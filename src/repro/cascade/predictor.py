"""Staged (cascade) ensemble evaluation over any registered engine.

The forest is partitioned into K tree-prefix stages; each stage's delta
sub-forest (trees ``[stages[k-1], stages[k])``) is compiled through the
ordinary engine pipeline, and between stages a ``GatePolicy`` decides
which rows exit early.  Surviving rows are gathered into a shrinking
batch, padded to the next power of two (``engine_select.bucket_batch``)
so every stage sees at most O(log B) distinct batch shapes — stage
retraces stay bounded exactly like the Pallas batch bucketing.

Exactness (docs/CASCADE.md): a row that reaches the last stage has
accumulated every tree's contribution, so with the gate disabled
(``MarginGate(inf)`` or a single stage) the cascade computes the same
function as the underlying engine — bit-exact on quantized forests
(integer partial sums, power-of-two leaf scale: the same argument as
tree-sharded execution, DESIGN.md §5).

``CascadePredictor`` satisfies the ``core.registry.Predictor`` protocol
(predict / predict_class / predict_proba / transform_inputs, plus
``host_forest``), serves through ``ForestServer`` (per-stage exit
fractions land in ``ServerStats``), and round-trips through packed
``.repro.npz`` artifacts (``io.save_predictor`` / ``io.load_predictor``,
kind="cascade") including the gate thresholds.
"""
from __future__ import annotations

import copy
import dataclasses
from dataclasses import dataclass
from typing import Optional, Sequence

import numpy as np

from ..core import registry
from ..core.engine_select import bucket_batch
from ..core.forest import Forest
from ..core.quantize import quantize_inputs
from ..core.registry import normalize_scores
from .policy import GatePolicy, MarginGate


def default_policy() -> GatePolicy:
    return MarginGate(0.9)


@dataclass(frozen=True)
class CascadeSpec:
    """Declarative cascade request: stage boundaries (cumulative tree
    counts — ``(16, 48, 192)`` evaluates 16 trees, then 32 more, then
    144 more) plus the gate policy.  ``policy=None`` → ``MarginGate(0.9)``.
    ``fused=True`` lowers to ``FusedCascadePredictor`` (one jitted
    computation, zero host syncs between stages — docs/CASCADE.md).
    Passed to ``core.compile_forest(..., cascade=...)`` /
    ``compile_plan`` and swept by the autotuner via ``cascade_specs=``."""
    stages: tuple
    policy: Optional[GatePolicy] = None
    fused: bool = False

    def resolved_policy(self) -> GatePolicy:
        return self.policy if self.policy is not None else default_policy()

    def tag(self) -> str:
        """Autotuner candidate tag, e.g. ``cascade=16/48:margin0.9`` or
        ``cascade-fused=16/48:margin0.9``.  Every field that changes the
        compiled variant participates, so distinct cascades never alias
        in the timing cache — fused tags also key-miss any pre-fusion
        cache entries."""
        s = "/".join(str(int(x)) for x in self.stages)
        kind = "cascade-fused" if self.fused else "cascade"
        return f"{kind}={s}:{self.resolved_policy().tag()}"


def normalize_stages(stages: Sequence[int], n_trees: int) -> tuple:
    """Sorted unique positive boundaries, clamped to ``n_trees``; the
    final stage always covers the whole forest (appended if missing)."""
    out = sorted({min(int(s), n_trees) for s in stages})
    if any(s <= 0 for s in out):
        raise ValueError(f"stage boundaries must be positive, got {stages}")
    if not out or out[-1] != n_trees:
        out.append(n_trees)
    return tuple(out)


def tree_slice(forest: Forest, start: int, stop: int) -> Forest:
    """Sub-forest of trees ``[start, stop)`` — shares the ensemble-wide
    padding (L) and all quantization metadata, so per-stage engine
    outputs descale identically to the full forest's."""
    sl = slice(start, stop)
    return dataclasses.replace(
        forest, n_trees=stop - start,
        feature=forest.feature[sl], threshold=forest.threshold[sl],
        left=forest.left[sl], right=forest.right[sl],
        leaf_lo=forest.leaf_lo[sl], leaf_mid=forest.leaf_mid[sl],
        leaf_hi=forest.leaf_hi[sl], leaf_value=forest.leaf_value[sl],
        n_nodes=forest.n_nodes[sl],
        n_leaves_per_tree=forest.n_leaves_per_tree[sl])


class CascadePredictor:
    """Confidence-gated staged evaluation wrapping any registered engine.

    ``stage_predictors`` injects pre-built per-stage predictors (the
    packed-artifact load path); otherwise each stage's delta sub-forest
    is compiled through ``core.registry.build`` with the given
    engine/backend/engine_kw.
    """

    def __init__(self, forest: Forest, spec: CascadeSpec, *,
                 engine: str = "bitvector", backend: str = "jax",
                 engine_kw: Optional[dict] = None,
                 stage_predictors: Optional[list] = None):
        self.forest = forest
        self.engine = engine
        self.backend = backend
        self.engine_kw = dict(engine_kw or {})
        self.stages = normalize_stages(spec.stages, forest.n_trees)
        bounds = (0,) + self.stages
        if stage_predictors is not None:
            if len(stage_predictors) != len(self.stages):
                raise ValueError(
                    f"{len(stage_predictors)} stage predictors for "
                    f"{len(self.stages)} stages {self.stages}")
            self.stage_predictors = list(stage_predictors)
        else:
            build = registry.get(engine, backend).builder()
            self.stage_predictors = [
                build(tree_slice(forest, bounds[k], bounds[k + 1]),
                      **self.engine_kw)
                for k in range(len(self.stages))]
        # quantize once, not once per surviving stage: every stage slice
        # shares the full forest's quantization metadata, so stages that
        # expose predict_transformed can all eat one pre-transformed
        # matrix (third-party Predictors without it fall back to raw
        # rows + their own transform)
        self._pre_transform = all(
            hasattr(p, "predict_transformed") for p in self.stage_predictors)
        self.set_policy(spec.resolved_policy())
        self.reset_exit_stats()

    # ------------------------------------------------------------- policy
    def set_policy(self, policy: GatePolicy) -> None:
        """Install (a copy of) ``policy``, prepared for this cascade's
        forest and stages — e.g. the winner of ``policy.calibrate``."""
        self.policy = copy.copy(policy)
        self.policy.prepare(self.forest, self.stages)

    #: class-level flag — ``FusedCascadePredictor`` flips it; drives the
    #: spec/tag/describe/serialization split between the two variants
    fused = False

    @property
    def spec(self) -> CascadeSpec:
        return CascadeSpec(stages=self.stages, policy=self.policy,
                           fused=self.fused)

    def describe(self) -> str:
        s = "/".join(str(x) for x in self.stages)
        d = f"stages={s} policy={self.policy.tag()}"
        return f"fused {d}" if self.fused else d

    @property
    def host_syncs(self) -> int:
        """Device→host synchronizations per ``predict`` batch: the staged
        loop materializes every stage's scores on the host for the gate
        (one sync per stage); the fused predictor overrides this with 1."""
        return len(self.stages)

    def trace_cache_size(self) -> Optional[int]:
        """Total XLA trace-cache entries across the stage predictors —
        the retrace-detection surface (``repro.obs.retrace``): a growth
        after serving warmup means some stage saw a cold shape.  ``None``
        when no stage exposes a cache (monitoring degrades to no-op)."""
        from ..obs.retrace import fn_cache_size
        total, found = 0, False
        for p in self.stage_predictors:
            size = fn_cache_size(getattr(p, "_fn", None))
            if size is not None:
                total, found = total + size, True
        return total if found else None

    # ------------------------------------------------------------ serving
    def reset_exit_stats(self) -> None:
        K = len(self.stages)
        self.last_exit_counts = np.zeros(K, dtype=np.int64)
        self.exit_counts = np.zeros(K, dtype=np.int64)

    @property
    def exit_fractions(self) -> np.ndarray:
        """Cumulative per-stage exit fractions over every ``predict``
        since the last ``reset_exit_stats`` (sums to 1 once any row ran)."""
        tot = int(self.exit_counts.sum())
        return self.exit_counts / max(tot, 1)

    @property
    def mean_trees_evaluated(self) -> float:
        """Mean trees evaluated per row under the cumulative exit counts
        (the cascade's work metric: full forest = ``n_trees``)."""
        tot = int(self.exit_counts.sum())
        if tot == 0:
            return float(self.forest.n_trees)
        return float((self.exit_counts * np.asarray(self.stages)).sum() / tot)

    # --------------------------------------------------------- prediction
    def transform_inputs(self, X: np.ndarray) -> np.ndarray:
        return quantize_inputs(self.forest, np.asarray(X))

    def host_forest(self) -> Forest:
        return self.forest

    def _stage_scores(self, k: int, X: np.ndarray) -> np.ndarray:
        """One stage's delta scores for the active rows, padded to the
        power-of-two bucket so stage recompiles stay bounded.  ``X`` is
        pre-transformed when ``_pre_transform`` is set, raw otherwise."""
        n = X.shape[0]
        bucket = bucket_batch(n)
        if bucket > n:
            # zero rows, not repeats of row 0: a pathological first row
            # would otherwise be re-evaluated up to bucket - n times per
            # stage; the padding is sliced off before any gate sees it
            X = np.concatenate(
                [X, np.zeros((bucket - n,) + X.shape[1:], dtype=X.dtype)])
        pred = self.stage_predictors[k]
        out = pred.predict_transformed(X) if self._pre_transform \
            else pred.predict(X)
        return out[:n]

    def predict(self, X: np.ndarray) -> np.ndarray:
        """(B, d) → (B, C) scores.  Rows that exit early return their
        cumulative prefix scores (partial vote/logit mass); rows that
        reach the last stage carry the exact full-forest score."""
        X = np.asarray(X)
        feed = self.transform_inputs(X) if self._pre_transform else X
        B = X.shape[0]
        K = len(self.stages)
        out = np.zeros((B, self.forest.n_classes), dtype=np.float32)
        counts = np.zeros(K, dtype=np.int64)
        active = np.arange(B)
        for k in range(K):
            if active.size == 0:
                break
            out[active] += self._stage_scores(k, feed[active])
            if k == K - 1:
                counts[k] += active.size
                break
            ex = self.policy.exits(out[active], k)
            counts[k] += int(ex.sum())
            active = active[~ex]
        self.last_exit_counts = counts
        self.exit_counts += counts
        return out

    def predict_class(self, X: np.ndarray) -> np.ndarray:
        return self.predict(X).argmax(axis=1)

    def predict_proba(self, X: np.ndarray) -> np.ndarray:
        # same votes-vs-logits rule as the gate's confidence normalization
        return normalize_scores(self.predict(X),
                                votes=registry.votes_mode(self.forest))

    def cumulative_scores(self, X: np.ndarray) -> np.ndarray:
        """(K, B, C) cumulative scores after each stage with the gate
        held open — every row through every stage.  The calibration
        input (``policy.calibrate`` / ``simulate_gate``); also the
        gate-disabled reference: ``cumulative_scores(X)[-1]`` equals the
        underlying engine's full-forest prediction."""
        X = np.asarray(X)
        feed = self.transform_inputs(X) if self._pre_transform else X
        acc = np.zeros((X.shape[0], self.forest.n_classes), dtype=np.float32)
        out = []
        for k in range(len(self.stages)):
            acc = acc + self._stage_scores(k, feed)
            out.append(acc)
        return np.stack(out)
