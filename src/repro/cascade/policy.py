"""Gating policies for staged (cascade) ensemble evaluation.

Daghero et al. ("Dynamic Decision Tree Ensembles for Energy-Efficient
Inference on IoT Edge Nodes", PAPERS.md) observe that most inputs are
decided by a small prefix of the ensemble: a confidence gate between
stages routes only the hard inputs to the rest of the forest.  This
module holds the gate side of the subsystem (docs/CASCADE.md):

  * ``GatePolicy`` — the pluggable interface: ``prepare(forest, stages)``
    precomputes whatever per-stage state the gate needs from the host IR,
    ``decide(scores, stage)`` is the **pure-jax** decision rule mapping
    the batch's *cumulative* stage scores to a boolean exit mask, and
    ``exits(scores, stage)`` is its numpy-facing wrapper.  The staged
    host loop and the fused in-graph cascade (``cascade/fused.py``) both
    run the *same* jitted ``decide``, so their per-stage exit counts are
    identical by construction.
  * ``MarginGate`` / ``ProbaGate`` — heuristic confidence gates for
    classification forests: exit when the normalized top-1/top-2 margin
    (or the top-1 probability) clears a threshold.  ``threshold=inf``
    never fires — the conformance suite's "gate disabled" case.
  * ``ScoreBoundGate`` — *sound* early exit via remaining-score bounds:
    per-tree leaf min/max of the not-yet-evaluated trees bound how much
    the score can still move; a row exits only when its decision provably
    cannot flip (at ``slack=0``, ``predict_class`` equals the full
    forest's — bit-exactly on quantized forests; on float forests up to
    the stage-split f32 summation rounding, which can flip genuine
    near-ties).  This is the GBM-shaped gate (remaining logit mass), but
    it is defined for any leaf semantics.
  * ``calibrate()`` — picks the cheapest policy from a candidate grid
    whose held-out accuracy stays within ``floor_pp`` percentage points
    of the full forest, simulated on cumulative stage scores so no
    predictor is rebuilt per threshold.

Policies carry only scalar config in their init fields (serialized into
packed cascade artifacts by ``io/packed.py``); everything ``prepare``
derives is rebuilt from the forest on load.
"""
from __future__ import annotations

import importlib
from dataclasses import dataclass, field, fields
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..core.engine_select import bucket_batch
from ..core.forest import Forest
from ..core.quantize import leaf_scale
from ..core.registry import votes_mode


def normalize_scores_jnp(scores: jnp.ndarray, votes: bool) -> jnp.ndarray:
    """Traceable twin of ``registry.normalize_scores`` in canonical f32:
    vote counts normalize by total mass (all-zero rows fall back to
    uniform), margins/logits go through softmax.  It tolerates partial
    sums — a vote prefix simply has less total mass — so gate confidence
    and served ``predict_proba`` use the same rule.  Callers guard
    C >= 2.  Every op lowers inside a Pallas kernel body, so the fused
    cascade kernel can evaluate gates in-kernel."""
    s = scores.astype(jnp.float32)
    if votes:
        v = jnp.maximum(s, 0.0)
        tot = jnp.sum(v, axis=1, keepdims=True)
        uniform = jnp.float32(1.0 / s.shape[1])
        return jnp.where(tot > 0, v / jnp.where(tot > 0, tot, 1.0), uniform)
    m = jnp.max(s, axis=1, keepdims=True)
    e = jnp.exp(s - m)
    return e / jnp.sum(e, axis=1, keepdims=True)


def _f32_down(x64: np.ndarray) -> np.ndarray:
    """f64 → f32 rounding toward -inf (exact values pass through)."""
    x32 = x64.astype(np.float32)
    hi = x32.astype(np.float64) > x64
    return np.where(hi, np.nextafter(x32, -np.inf), x32).astype(np.float32)


def _f32_up(x64: np.ndarray) -> np.ndarray:
    """f64 → f32 rounding toward +inf (exact values pass through)."""
    x32 = x64.astype(np.float32)
    lo = x32.astype(np.float64) < x64
    return np.where(lo, np.nextafter(x32, np.inf), x32).astype(np.float32)


def _argmax_onehot(s: jnp.ndarray) -> jnp.ndarray:
    """(n, C) → boolean one-hot of the *first* row maximum — matches
    ``np.argmax`` tie-breaking without ``argmax``/``one_hot`` ops (both
    awkward inside Mosaic kernel bodies: plain compare/cumsum lower
    everywhere)."""
    eq = s == jnp.max(s, axis=1, keepdims=True)
    return eq & (jnp.cumsum(eq.astype(jnp.int32), axis=1) == 1)


@dataclass
class GatePolicy:
    """Interface: subclasses implement ``decide`` (and usually ``prepare``).

    ``prepare(forest, stages)`` is called once per cascade build with the
    *host* forest and the normalized stage boundaries (cumulative tree
    counts, last == n_trees).  ``decide(scores, stage)`` is the pure-jax
    decision rule: cumulative descaled scores (n, C) f32 → boolean (n,)
    mask, True exits now.  It must be traceable (the fused cascade calls
    it inside one jitted program — for the bitvector Pallas path, inside
    the kernel body itself), with ``stage`` a static Python int.

    ``exits(scores, stage)`` is the numpy-facing wrapper the staged host
    loop calls between stages: it pads to the power-of-two batch bucket
    and runs the *same jitted* ``decide``, so staged and fused cascades
    make bit-identical gate decisions by construction.  Third-party
    policies may still override ``exits`` directly (numpy-only); such
    policies work with the staged ``CascadePredictor`` but cannot be
    fused."""

    def prepare(self, forest: Forest, stages: Sequence[int]) -> None:
        self._decide_jit = None

    def decide(self, scores: jnp.ndarray, stage: int) -> jnp.ndarray:
        raise NotImplementedError(
            f"{type(self).__name__} defines no pure-jax decide(); "
            "implement it (or override exits() and use the staged "
            "CascadePredictor — fused execution requires decide)")

    def exits(self, scores: np.ndarray, stage: int) -> np.ndarray:
        n = scores.shape[0]
        if n == 0:
            return np.zeros(0, dtype=bool)
        fn = getattr(self, "_decide_jit", None)
        if fn is None:
            # cache per prepared instance: decide closes over prepared
            # state, so prepare() resets the cache (set_policy copies
            # the policy before preparing — a stale trace never leaks)
            fn = self._decide_jit = jax.jit(self.decide,
                                            static_argnums=(1,))
        bucket = bucket_batch(n)
        s = np.zeros((bucket,) + scores.shape[1:], dtype=np.float32)
        s[:n] = scores
        return np.asarray(fn(jnp.asarray(s), stage))[:n]

    def tag(self) -> str:
        """Short candidate-name tag (autotuner cache: distinct configs
        must never alias — every init field participates)."""
        raise NotImplementedError


@dataclass
class MarginGate(GatePolicy):
    """Exit when the top-1 vs top-2 probability margin >= ``threshold``.

    ``threshold=inf`` never exits (gate disabled).  On C<2 forests
    (regression / ranking) no margin exists, so the gate never fires —
    use ``ScoreBoundGate`` there."""
    threshold: float = 0.9

    _votes: bool = field(default=True, init=False, repr=False, compare=False)
    _n_classes: int = field(default=1, init=False, repr=False, compare=False)

    def prepare(self, forest: Forest, stages: Sequence[int]) -> None:
        super().prepare(forest, stages)
        self._votes = votes_mode(forest)
        self._n_classes = forest.n_classes

    def decide(self, scores: jnp.ndarray, stage: int) -> jnp.ndarray:
        if self._n_classes < 2 or not np.isfinite(self.threshold):
            return jnp.zeros(scores.shape[0], dtype=bool)
        p = normalize_scores_jnp(scores, votes=self._votes)
        top = jnp.max(p, axis=1)
        second = jnp.max(jnp.where(_argmax_onehot(p), -jnp.inf, p), axis=1)
        return (top - second) >= jnp.float32(self.threshold)

    def tag(self) -> str:
        return f"margin{self.threshold:g}"


@dataclass
class ProbaGate(MarginGate):
    """Exit when the top-1 probability >= ``threshold``."""
    threshold: float = 0.95

    def decide(self, scores: jnp.ndarray, stage: int) -> jnp.ndarray:
        if self._n_classes < 2 or not np.isfinite(self.threshold):
            return jnp.zeros(scores.shape[0], dtype=bool)
        p = normalize_scores_jnp(scores, votes=self._votes)
        return jnp.max(p, axis=1) >= jnp.float32(self.threshold)

    def tag(self) -> str:
        return f"proba{self.threshold:g}"


@dataclass
class ScoreBoundGate(GatePolicy):
    """Sound early exit: remaining-score bounds from per-tree leaf
    min/max of the trees a row has not yet evaluated.

    After stage ``k`` a row's final score lies in
    ``[s + rest_min[k], s + rest_max[k]]`` componentwise.  A row exits
    when its decision provably cannot change:

      * C >= 2 — the current argmax class stays argmax even if every
        remaining tree votes worst-case against it;
      * C == 1 — the score's sign vs ``decision`` (GBM binary logit
        boundary, default 0) is already fixed.

    ``slack > 0`` relaxes soundness by that much score mass (exits
    earlier, may flip decisions by <= slack); ``slack = 0`` keeps
    ``predict_class`` equal to the full forest's — exactly so on
    quantized forests (integer stage sums); on float forests the
    cascade's stage-split f32 accumulation rounds differently from the
    base engine's single reduction, so a genuine near-tie (~1 ulp) can
    still resolve differently."""
    slack: float = 0.0
    decision: float = 0.0

    _rest_min: Optional[np.ndarray] = field(default=None, init=False,
                                            repr=False, compare=False)
    _rest_max: Optional[np.ndarray] = field(default=None, init=False,
                                            repr=False, compare=False)

    def prepare(self, forest: Forest, stages: Sequence[int]) -> None:
        super().prepare(forest, stages)
        raw = np.asarray(forest.leaf_value)
        scale = leaf_scale(forest)
        T, L, C = raw.shape
        real = np.arange(L)[None, :] < \
            np.asarray(forest.n_leaves_per_tree)[:, None]       # (T, L)
        bounds = [int(min(s, T)) for s in stages]
        if np.issubdtype(raw.dtype, np.integer):
            # quantized forests: exact integer gate arithmetic
            # (docs/QUANT.md).  Per-tree min/max and the suffix sums run
            # in int64 — no rounding anywhere — and the pow2 leaf-scale
            # descale is exact in f64.  When every bound is
            # f32-representable (always, in practice: |bound| < 2^24
            # scaled units) the cast is value-exact and no outward
            # rounding is applied — the gate bounds are bit-exact, the
            # soundness interval is tight.
            lv = raw.astype(np.int64)
            imin, imax = np.iinfo(np.int64).min, np.iinfo(np.int64).max
            tree_min = np.where(real[..., None], lv, imax).min(axis=1)
            tree_max = np.where(real[..., None], lv, imin).max(axis=1)
            zero = np.zeros((1, C), dtype=np.int64)
            suf_min = np.concatenate(
                [np.cumsum(tree_min[::-1], axis=0)[::-1], zero])
            suf_max = np.concatenate(
                [np.cumsum(tree_max[::-1], axis=0)[::-1], zero])
            rmin64 = np.stack([suf_min[b] for b in bounds]) / scale
            rmax64 = np.stack([suf_max[b] for b in bounds]) / scale
            rmin32 = rmin64.astype(np.float32)
            rmax32 = rmax64.astype(np.float32)
            if (np.all(rmin32.astype(np.float64) == rmin64)
                    and np.all(rmax32.astype(np.float64) == rmax64)):
                self._rest_min, self._rest_max = rmin32, rmax32
            else:        # bounds beyond f32's exact-integer range
                self._rest_min = _f32_down(rmin64)
                self._rest_max = _f32_up(rmax64)
            return
        lv = raw.astype(np.float64) / scale               # descaled, like scores
        tree_min = np.where(real[..., None], lv, np.inf).min(axis=1)   # (T, C)
        tree_max = np.where(real[..., None], lv, -np.inf).max(axis=1)
        # suffix sums: bounds over trees [stages[k], T) for each gate k
        suf_min = np.concatenate([np.cumsum(tree_min[::-1], axis=0)[::-1],
                                  np.zeros((1, C))])
        suf_max = np.concatenate([np.cumsum(tree_max[::-1], axis=0)[::-1],
                                  np.zeros((1, C))])
        # f32 (decide's canonical dtype), rounded *outward*: a
        # round-to-nearest cast could shrink an interval by 1 ulp and
        # make a "provably decided" row exit unsoundly on float forests
        self._rest_min = _f32_down(np.stack([suf_min[b] for b in bounds]))
        self._rest_max = _f32_up(np.stack([suf_max[b] for b in bounds]))

    def decide(self, scores: jnp.ndarray, stage: int) -> jnp.ndarray:
        s = scores.astype(jnp.float32)
        C = s.shape[1]
        # per-class bounds as python-float literals, not a constant array:
        # Pallas kernel bodies reject captured array constants, and the
        # f32 → float → f32 trip is value-exact
        lo = jnp.stack([s[:, c] + float(self._rest_min[stage][c])
                        for c in range(C)], axis=1)
        hi = jnp.stack([s[:, c] + float(self._rest_max[stage][c])
                        for c in range(C)], axis=1)
        if s.shape[1] < 2:
            return ((lo[:, 0] > self.decision - self.slack) |
                    (hi[:, 0] < self.decision + self.slack))
        onehot = _argmax_onehot(s)
        best_lo = jnp.sum(jnp.where(onehot, lo, 0.0), axis=1)
        other_hi = jnp.max(jnp.where(onehot, -jnp.inf, hi), axis=1)
        return best_lo > other_hi - jnp.float32(self.slack)

    def tag(self) -> str:
        t = "bound"
        if self.slack:
            t += f"{self.slack:g}"
        if self.decision:
            t += f"@d{self.decision:g}"
        return t


# --------------------------------------------------------------------------- #
# (De)serialization of policy config — packed cascade artifacts
# --------------------------------------------------------------------------- #
def policy_to_header(policy: GatePolicy) -> dict:
    """Policy → JSON-safe header dict: class path + init-field scalars.
    Derived (``prepare``) state is rebuilt from the forest on load.
    Non-finite floats (a disabled gate is ``MarginGate(inf)``) are
    encoded as tagged strings — ``json.dumps`` would otherwise emit the
    non-RFC-8259 literal ``Infinity`` into the packed header."""
    cfg = {}
    for f in fields(policy):
        if not f.init:
            continue
        v = getattr(policy, f.name)
        if not isinstance(v, (bool, int, float, str)) and v is not None:
            raise TypeError(f"policy field {f.name!r} of "
                            f"{type(policy).__name__} is not a scalar "
                            f"({type(v).__name__}) — cannot serialize")
        if isinstance(v, float) and not np.isfinite(v):
            v = {"__float__": repr(v)}          # 'inf' / '-inf' / 'nan'
        cfg[f.name] = v
    t = type(policy)
    return {"class": f"{t.__module__}:{t.__qualname__}", "config": cfg}


def policy_from_header(h: dict) -> GatePolicy:
    mod, attr = h["class"].split(":")
    cls = getattr(importlib.import_module(mod), attr)
    if not (isinstance(cls, type) and issubclass(cls, GatePolicy)):
        raise ValueError(f"{h['class']!r} is not a GatePolicy subclass")
    cfg = {k: float(v["__float__"])
           if isinstance(v, dict) and "__float__" in v else v
           for k, v in h.get("config", {}).items()}
    return cls(**cfg)


# --------------------------------------------------------------------------- #
# Gate simulation + threshold calibration
# --------------------------------------------------------------------------- #
def simulate_gate(policy: GatePolicy, cum_scores: np.ndarray
                  ) -> tuple[np.ndarray, np.ndarray]:
    """Replay the gate on precomputed cumulative stage scores.

    ``cum_scores`` is (K, B, C) — the score each row would have after
    stage k if it were still active (``CascadePredictor.cumulative_scores``).
    Returns ``(exit_stage (B,) int, final_scores (B, C))`` — exactly what
    a gated ``predict`` would produce, without re-running any engine.
    The policy must already be ``prepare``'d for these stages."""
    K, B, C = cum_scores.shape
    exit_stage = np.full(B, K - 1, dtype=np.int64)
    active = np.ones(B, dtype=bool)
    for k in range(K - 1):
        idx = np.nonzero(active)[0]
        if idx.size == 0:
            break
        ex = policy.exits(cum_scores[k, idx], k)
        exit_stage[idx[ex]] = k
        active[idx[ex]] = False
    final = cum_scores[exit_stage, np.arange(B)]
    return exit_stage, final


@dataclass
class CalibrationResult:
    policy: GatePolicy            # winner (prepared for the stages)
    accuracy: float               # held-out accuracy of the gated cascade
    full_accuracy: float          # held-out accuracy of the full forest
    mean_trees: float             # mean trees evaluated per row (gated)
    exit_fractions: list          # per-stage exit fraction under the winner
    table: list                   # one dict per candidate policy tried

    @property
    def accuracy_drop_pp(self) -> float:
        return (self.full_accuracy - self.accuracy) * 100.0


def default_policy_grid() -> list:
    return [MarginGate(t) for t in
            (0.5, 0.6, 0.7, 0.8, 0.9, 0.95, 0.99)] + [ScoreBoundGate()]


def calibrate(pred, X_val: np.ndarray, y_val: np.ndarray, *,
              policies: Optional[Sequence[GatePolicy]] = None,
              floor_pp: float = 0.5) -> CalibrationResult:
    """Pick the cheapest gate whose held-out accuracy stays within
    ``floor_pp`` percentage points of the full forest.

    ``pred`` is a ``CascadePredictor`` (its stages are fixed; only the
    policy is swept).  Every candidate is simulated on one set of
    cumulative stage scores — no engine recompiles, no per-threshold
    predictions.  The contract: among candidates satisfying
    ``accuracy >= full_accuracy - floor_pp/100``, the one with the
    fewest mean trees evaluated wins; if none qualifies, the gate is
    disabled (``MarginGate(inf)`` — full forest, zero drop).  The
    returned policy is prepared; install it with ``pred.set_policy``."""
    y_val = np.asarray(y_val)
    cum = pred.cumulative_scores(X_val)                  # (K, B, C)
    stages = np.asarray(pred.stages, dtype=np.float64)
    full_cls = cum[-1].argmax(axis=1)
    full_acc = float((full_cls == y_val).mean())
    floor = full_acc - floor_pp / 100.0

    if policies is None:
        policies = default_policy_grid()
    candidates = list(policies) + [MarginGate(float("inf"))]  # safe fallback
    table = []
    best = None
    for pol in candidates:
        pol.prepare(pred.forest, pred.stages)
        exit_stage, final = simulate_gate(pol, cum)
        acc = float((final.argmax(axis=1) == y_val).mean())
        mean_trees = float(stages[exit_stage].mean())
        counts = np.bincount(exit_stage, minlength=len(pred.stages))
        row = {"policy": pol.tag(), "accuracy": acc,
               "mean_trees": mean_trees,
               "exit_fractions": (counts / max(len(y_val), 1)).tolist(),
               "ok": acc >= floor}
        table.append(row)
        if row["ok"] and (best is None
                          or mean_trees < best[0]
                          or (mean_trees == best[0] and acc > best[1])):
            best = (mean_trees, acc, pol, row)
    _, _, pol, row = best              # fallback always qualifies (acc==full)
    return CalibrationResult(policy=pol, accuracy=row["accuracy"],
                             full_accuracy=full_acc,
                             mean_trees=row["mean_trees"],
                             exit_fractions=row["exit_fractions"],
                             table=table)
