"""Fused cascade execution: the whole staged evaluation in ONE jit.

The staged ``CascadePredictor.predict`` pays a host round-trip at every
stage boundary — scores come back to numpy for the gate, survivors are
gathered and re-padded on the host, and each stage shape dispatches its
own compiled call.  BENCH_cascade.json shows where that leaves us: on
mnist a modest tree reduction *regresses* to 0.67× wall-clock.  This
module closes the gap by lowering stage scoring, gate decision, and
survivor masking into a single jitted computation with zero host syncs
between stages.

Execution scheme (per stage, inside one trace):

  1. **Stage 0** — every valid row is active by definition, so the
     padded batch evaluates in one vectorized call, exactly like the
     staged loop's first stage.
  2. **Compact** — before each later stage a prefix-sum over the
     survivor mask ranks active rows first (in original order), exited
     rows after, and a scatter turns the ranks into a permutation —
     O(B) adds, no sort.
  3. **Bucket dispatch** — ``lax.switch`` picks the smallest
     power-of-two prefix of the compacted batch that covers the
     survivor count and evaluates only that prefix, vectorized.  This
     is the in-graph twin of the staged loop's ``bucket_batch``
     shrinking batches (same bucket sizes, so the same compute), traded
     against a full-batch masked sweep which would burn every exited
     lane for zero savings.  Branch 0 is a no-op: when the survivor
     count hits zero, remaining stages dispatch to it — early
     termination without leaving the graph.
  4. **Scatter + gate** — the prefix's delta scores scatter back
     through the permutation (overrun lanes masked to zero), then the
     policy's pure-jax ``decide(scores, stage)`` — the same jitted rule
     the staged loop's ``exits`` wraps — marks exits, and per-stage
     exit counts accumulate in-graph.  ``ServerStats`` accounting costs
     exactly one device→host sync per batch.

Rows that exit keep their frozen cumulative score — identical semantics
to the staged loop, and bit-exact against it on quantized forests: the
per-row traversal is batch-composition independent, integer partial
sums make every reduction order agree, and the gate sees the same f32
values either way (the conformance suite pins this for every engine).

For the bitvector engine on the Pallas backend a second tier replaces
the per-stage program with one fused kernel (``kernels.cascade_kernel``):
stage tree-blocks evaluate under an in-kernel survivor mask held in
VMEM scratch, and a fully-decided batch tile skips all remaining stage
blocks via ``pl.when``.

When does the staged host loop still win?  Tiny batches (a handful of
rows — compaction/scatter overhead against a couple of cheap syncs)
and third-party policies that only implement the numpy ``exits``.
Everything else should prefer ``fused=True``; ``engine_select.choose``
times both when given both specs.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from ..core.engine_select import bucket_batch
from ..core.forest import Forest
from ..core.registry import ensure_feature_column
from .predictor import CascadePredictor, CascadeSpec


def _stage_eval_fn(pred):
    """One stage predictor → a traceable ``X -> (n, C) descaled scores``.

    Registry engines share ``BasePredictor._fn`` (a jitted closure over
    the compiled arrays — calling it under an outer jit inlines the
    trace); Pallas predictors expose the same ``_fn`` but descale on the
    host, so the leaf scale is divided out here to match
    ``predict_transformed`` exactly."""
    fn = getattr(pred, "_fn", None)
    if fn is None:
        raise TypeError(
            f"stage predictor {type(pred).__name__} exposes no traceable "
            "eval fn (_fn) — fused cascade execution needs one; use the "
            "staged CascadePredictor for this engine")
    scale = getattr(pred, "leaf_scale", None)
    if scale is not None and scale != 1.0:
        return lambda X: fn(X) / jnp.float32(scale)
    return fn


class FusedCascadePredictor(CascadePredictor):
    """Drop-in ``CascadePredictor`` whose ``predict`` is one compiled
    computation (module docstring).  Stage building, policy handling,
    calibration (``cumulative_scores``), exit-stat accounting, and the
    packed-artifact protocol are all inherited — only the hot path and
    its sync count change."""

    fused = True

    def __init__(self, forest: Forest, spec: CascadeSpec, *,
                 engine: str = "bitvector", backend: str = "jax",
                 engine_kw: Optional[dict] = None,
                 stage_predictors: Optional[list] = None):
        super().__init__(forest, spec, engine=engine, backend=backend,
                         engine_kw=engine_kw, stage_predictors=stage_predictors)
        self._stage_fns = [_stage_eval_fn(p) for p in self.stage_predictors]
        blocks = [p.block_b for p in self.stage_predictors
                  if hasattr(p, "block_b")]
        # Pallas stages demand f32 rows padded to their batch block; it
        # also floors the bucket ladder so every switch branch tiles
        self._row_mult = max(blocks) if blocks else 1
        self._feed_f32 = bool(blocks)
        # the bitvector/pallas pair gets the single-kernel tier
        self._use_kernel = (engine == "bitvector" and backend == "pallas"
                            and stage_predictors is None)

    # ------------------------------------------------------------- policy
    def set_policy(self, policy) -> None:
        super().set_policy(policy)
        # the fused traces close over the policy — stale jits must die
        self._jit_cache = {}

    def trace_cache_size(self) -> Optional[int]:
        """Stage caches (inherited surface) plus the fused program's own
        jit cache — ``obs.retrace.CompileWatch`` treats the cache drop
        after ``set_policy`` as a deliberate reset, not negative
        compiles."""
        from ..obs.retrace import fn_cache_size
        total = super().trace_cache_size()
        found = total is not None
        total = total or 0
        for fn in self._jit_cache.values():
            size = fn_cache_size(fn)
            if size is not None:
                total, found = total + size, True
        return total if found else None

    # -------------------------------------------------------- fused trace
    def _bucket_ladder(self, Bp: int) -> list:
        """Switch-branch sizes: ``F·2^j`` and ``3F·2^j`` up to Bp, F the
        floor (16 rows, or the Pallas batch block — both families stay
        multiples of the block).  The half-steps cap the worst-case
        over-evaluation at 1.5× instead of 2×, which is what decides
        the low-exit regime (mnist: ~73 % of rows reach the last
        stage); the floor keeps the branch count — and with it compile
        time and conditional dispatch — modest."""
        floor = min(max(16, self._row_mult), Bp)
        half = self._row_mult if self._row_mult > 1 \
            else max(floor // 2, 1)
        sizes = set([Bp])
        s = floor
        while s < Bp:
            sizes.add(s)
            s *= 2
        # finer steps only near the top, where over-evaluation is paid
        # in real tree traversals (a 57 %-survivor stage at a 2× bucket
        # nearly doubles its cost); below Bp/4 the absolute waste is
        # small and every extra branch taxes compile + dispatch
        for m, lo in ((3, Bp // 4), (5, Bp // 2), (7, Bp // 2)):
            s = m * half
            while s < Bp:
                if s >= max(floor, lo):
                    sizes.add(s)
                s *= 2
        return sorted(sizes)

    def _fused_program(self):
        """Tier-1 generic program: ``(Xp, n) -> (scores, counts)`` over
        a (Bp, d) zero-padded batch, Bp a multiple of row_mult; the
        first ``n`` rows are real."""
        stage_fns = self._stage_fns
        decide = self.policy.decide
        K = len(self.stages)
        C = self.forest.n_classes

        def run(Xp, n):
            Bp = Xp.shape[0]
            iota = jnp.arange(Bp, dtype=jnp.int32)
            acc = jnp.zeros((Bp, C), dtype=jnp.float32)
            counts = jnp.zeros((K,), dtype=jnp.int32)
            active = iota < n
            n_act = n.astype(jnp.int32)
            sizes = self._bucket_ladder(Bp)
            sizes_arr = jnp.asarray(sizes, dtype=jnp.int32)

            for k in range(K):
                if k == 0:
                    # every valid row is active and valid rows are a
                    # prefix: the identity permutation compacts
                    order = iota
                else:
                    # compact survivors to the front: prefix-sum ranks
                    # (no sort — an XLA sort over Bp keys costs more
                    # than the small stage evals it feeds), scattered
                    # into a permutation; original row order preserved
                    na = active.astype(jnp.int32)
                    pos = jnp.where(active, jnp.cumsum(na) - 1,
                                    n_act + jnp.cumsum(1 - na) - 1)
                    order = jnp.zeros(Bp, jnp.int32).at[pos].set(iota)

                def mk(size, _k=k, _order=order, _n=n_act):
                    def branch(a):
                        # gather only the bucket's rows, in-branch; the
                        # overrun lanes (exited or padded rows) are
                        # masked so frozen scores stay frozen
                        delta = stage_fns[_k](Xp[_order[:size]])
                        ok = jnp.arange(size) < _n
                        return a.at[_order[:size]].add(
                            jnp.where(ok[:, None], delta, 0.0))
                    return branch

                # smallest bucket covering the survivors; 0 → no-op
                # (early termination once everything has exited)
                idx = jnp.where(
                    n_act > 0,
                    1 + jnp.sum((sizes_arr < n_act).astype(jnp.int32)),
                    0)
                acc = lax.switch(idx, [lambda a: a]
                                 + [mk(s) for s in sizes], acc)
                if k == K - 1:
                    counts = counts.at[k].add(n_act)
                else:
                    ex = decide(acc, k) & active
                    nex = jnp.sum(ex.astype(jnp.int32))
                    counts = counts.at[k].add(nex)
                    active = active & ~ex
                    n_act = n_act - nex
            return acc, counts

        return run

    def _kernel_program(self):
        """Tier-2: the single Pallas cascade kernel plus in-graph exit
        accounting (per-row exit stage → one-hot → per-stage counts)."""
        from ..kernels import ops as kops
        fn = kops.pallas_fused_cascade_qs(
            self.forest, self.stages, self.policy, **self.engine_kw)
        K = len(self.stages)

        def run(Xp, n):
            valid = jnp.arange(Xp.shape[0], dtype=jnp.int32) < n
            scores, exit_stage = fn(Xp, valid)
            hot = (exit_stage == jnp.arange(K, dtype=jnp.int32)[None, :]) \
                & valid[:, None]
            return scores, jnp.sum(hot.astype(jnp.int32), axis=0)

        return run

    def _fused_call(self):
        fn = self._jit_cache.get("prog")
        if fn is None:
            run = self._kernel_program() if self._use_kernel \
                else self._fused_program()
            fn = self._jit_cache["prog"] = jax.jit(run)
        return fn

    # --------------------------------------------------------- prediction
    def predict(self, X: np.ndarray) -> np.ndarray:
        X = np.asarray(X)
        K = len(self.stages)
        if X.shape[0] == 0:
            self.last_exit_counts = np.zeros(K, dtype=np.int64)
            return np.zeros((0, self.forest.n_classes), dtype=np.float32)
        feed = ensure_feature_column(np.asarray(self.transform_inputs(X)))
        if self._feed_f32:
            feed = feed.astype(np.float32)
        n, mult = feed.shape[0], self._row_mult
        # same power-of-two bucketing as the staged loop / Pallas
        # predictors: O(log B) distinct shapes → O(log B) traces
        bucket = mult * bucket_batch(-(-n // mult)) if mult > 1 \
            else bucket_batch(n)
        Xp = np.zeros((bucket,) + feed.shape[1:], dtype=feed.dtype)
        Xp[:n] = feed
        scores, counts = self._fused_call()(jnp.asarray(Xp),
                                            np.int32(n))
        counts = np.asarray(counts, dtype=np.int64)   # the ONE host sync
        self.last_exit_counts = counts
        self.exit_counts += counts
        return np.asarray(scores)[:n]

    @property
    def host_syncs(self) -> int:
        return 1
