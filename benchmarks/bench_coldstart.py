"""Cold-start benchmark: load-to-first-prediction for the three start
paths the io subsystem enables (PACSET's deployment-latency metric):

  * ``import+compile``  — parse an external model dump (XGBoost JSON),
    canonicalize to the IR, compile the engine, predict once;
  * ``packed+compile``  — load the packed ``.repro.npz`` IR (padding
    stripped, traversal order), compile the engine, predict once;
  * ``packed-artifact`` — load the serialized compiled predictor
    (``io.save_predictor``) and predict once: no mask construction, no
    leaf packing, no autotune — the ``ForestServer.load`` restart path.

    PYTHONPATH=src python -m benchmarks.bench_coldstart
"""
from __future__ import annotations

import json
import os
import sys
import tempfile
import time

import numpy as np

from repro import core, io

from .common import Table, save_json, scale_pick


def _forest_to_xgb_dump(forest) -> list:
    """IR → XGBoost-dump JSON (the inverse of ``import_xgboost_json``'s
    threshold mapping, so round-tripped predictions agree)."""
    trees = []
    for t in range(forest.n_trees):
        ctr = [forest.nodes_per_tree]        # leaf nodeids after internals

        def node(n: int) -> dict:
            if n < 0:                                      # leaf code
                j = -n - 1
                return {"nodeid": ctr[0] + j,
                        "leaf": float(forest.leaf_value[t, j, 0])}
            thr = float(np.nextafter(np.float32(forest.threshold[t, n]),
                                     np.float32(np.inf)))
            left = node(int(forest.left[t, n]))
            right = node(int(forest.right[t, n]))
            return {"nodeid": int(n), "split": f"f{forest.feature[t, n]}",
                    "split_condition": thr, "yes": left["nodeid"],
                    "no": right["nodeid"], "missing": left["nodeid"],
                    "children": [left, right]}

        if forest.n_nodes[t] == 0:          # single-leaf tree
            trees.append({"nodeid": 0,
                          "leaf": float(forest.leaf_value[t, 0, 0])})
        else:
            trees.append(node(0))
    return trees


def _once(fn) -> tuple[float, object]:
    t0 = time.perf_counter()
    out = fn()
    return time.perf_counter() - t0, out


def run(engine: str = "bitvector", batch: int = 256):
    T, L, d = scale_pick((100, 32, 32), (200, 64, 64), (1024, 64, 136))
    forest = core.random_forest_ir(T, L, d, seed=7)
    X = np.random.default_rng(0).normal(size=(batch, d))
    tmp = tempfile.mkdtemp(prefix="repro_coldstart_")
    dump_path = os.path.join(tmp, "model.json")
    ir_path = os.path.join(tmp, "forest.repro.npz")
    art_path = os.path.join(tmp, "pred.repro.npz")
    with open(dump_path, "w") as f:
        json.dump(_forest_to_xgb_dump(forest), f)
    io.save_forest(forest, ir_path)
    io.save_predictor(core.compile_forest(forest, engine=engine), art_path)

    def path_import():
        pred = core.compile_forest(io.load_model(dump_path), engine=engine)
        return pred.predict(X)

    def path_packed():
        pred = core.compile_forest(io.load_forest(ir_path), engine=engine)
        return pred.predict(X)

    def path_artifact():
        return io.load_predictor(art_path).predict(X)

    t_imp, y_imp = _once(path_import)
    t_pack, y_pack = _once(path_packed)
    t_art, y_art = _once(path_artifact)
    # the three starts are the same model: predictions must agree
    np.testing.assert_allclose(y_pack, y_art, rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(y_imp, y_pack, rtol=1e-4, atol=1e-5)

    sizes = {"model.json": os.path.getsize(dump_path),
             "forest.repro.npz": os.path.getsize(ir_path),
             "pred.repro.npz": os.path.getsize(art_path)}
    tbl = Table("bench_coldstart",
                ["trees", "leaves", "engine", "import+compile_ms",
                 "packed+compile_ms", "packed-artifact_ms",
                 "artifact_speedup"])
    tbl.add(T, L, engine, f"{t_imp*1e3:.1f}", f"{t_pack*1e3:.1f}",
            f"{t_art*1e3:.1f}", f"{t_imp/t_art:.2f}x")
    records = {"trees": T, "leaves": L, "features": d, "batch": batch,
               "engine": engine,
               "seconds": {"import_compile": t_imp,
                           "packed_compile": t_pack,
                           "packed_artifact": t_art},
               "bytes": sizes}
    return tbl, records


def main(argv=None) -> int:
    tbl, records = run()
    tbl.print()
    tbl.save()
    save_json("bench_coldstart_raw", records)
    s = records["seconds"]
    print(f"\ncold start: packed artifact {s['import_compile']/s['packed_artifact']:.2f}x "
          f"faster than import+compile "
          f"({s['packed_artifact']*1e3:.0f}ms vs {s['import_compile']*1e3:.0f}ms)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
