"""Open-loop load benchmark for the concurrent serving runtime.

    PYTHONPATH=src python -m benchmarks.bench_serving            # table
    PYTHONPATH=src python -m benchmarks.bench_serving --json     # + snapshot
    PYTHONPATH=src python -m benchmarks.bench_serving --quick    # CI smoke

Arrivals are open-loop Poisson: request i's arrival is *scheduled* at
``base + Exp(rate)`` cumulative gaps and stamped as ``arrival_s``
regardless of when the driver thread actually manages to submit it — a
lagging driver inflates latency instead of silently throttling the
offered load (the closed-loop fallacy).  Four sections:

  * ``load``     — throughput vs p50/p99 latency across an offered-rate
    ladder, fixed batching (one warmed tenant, threaded runtime);
  * ``adaptive`` — adaptive vs fixed batching at the same offered load
    against a p99 budget: fixed ``max_wait_ms`` sits above the budget
    and misses it, the SLO controller shrinks its effective knobs and
    meets it (or beats fixed throughput at equal p99);
  * ``tenants``  — ≥ 2 tenants cold-started from packed ``.repro.npz``
    artifacts via the JSON manifest, mixed Poisson traffic, per-tenant
    stats; served scores checked bit-identical to the synchronous
    ``predictor.predict``;
  * ``warmup``   — first-request latency through the runtime, cold vs
    shape-warmed, on the fused-cascade XLA tier (fresh predictor each
    way, so cold really pays the trace/compile).

The CSV (experiments/bench/), the raw JSON, and the repo-root
``BENCH_serving.json`` snapshot all come from the **same** run's records
(PR-1's artifact-consistency rule).  Non-default ``REPRO_BENCH_SCALE``
(or ``--quick``) writes scale-suffixed artifacts and leaves the
canonical snapshot untouched.
"""
from __future__ import annotations

import argparse
import gc
import json
import os
import sys
import tempfile
import time

import numpy as np

from repro import core
from repro.cascade import CascadeSpec, MarginGate
from repro.inference import ServingRuntime, SLOConfig

from .common import SCALE, Table, save_json

REPO_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
SNAPSHOT = os.path.join(REPO_ROOT, "BENCH_serving.json")

P99_BUDGET_MS = 15.0       # the SLO the adaptive section is judged on;
                           # comfortably above this container's ~10 ms
                           # scheduler-jitter spikes, which no batching
                           # knob can buy back
FIXED_WAIT_MS = 25.0       # fixed batching's wait: above the budget

# instrumentation-overhead bound (docs/OBSERVABILITY.md): with the full
# observability layer on — metrics, spans, retrace polling, a live
# scrape endpoint — the served p99 must stay within
#   p99_on <= OBS_P99_FACTOR * p99_off + OBS_P99_SLACK_MS.
# The slack absorbs this container's scheduler-jitter tail (one ~10 ms
# descheduling event lands entirely in one run's p99); the factor is
# what catches a real per-request regression.
OBS_P99_FACTOR = 1.5
OBS_P99_SLACK_MS = 10.0


def _params(scale: str) -> dict:
    return {
        "quick": dict(trees=32, leaves=16, features=8, classes=3,
                      rates=(500.0,), n_req=150, n_req_adaptive=400,
                      cascade_stages=(8, 32)),
        "default": dict(trees=128, leaves=32, features=16, classes=3,
                        rates=(250.0, 1000.0, 4000.0), n_req=1500,
                        n_req_adaptive=2000, cascade_stages=(16, 128)),
        "full": dict(trees=256, leaves=64, features=32, classes=5,
                     rates=(250.0, 1000.0, 4000.0, 8000.0), n_req=5000,
                     n_req_adaptive=6000, cascade_stages=(32, 256)),
    }[scale]


def _forest(p, seed=0):
    rng = np.random.default_rng(seed)
    f = core.random_forest_ir(n_trees=p["trees"], n_leaves=p["leaves"],
                              n_features=p["features"],
                              n_classes=p["classes"], seed=seed)
    return core.quantize_forest(f, rng.normal(size=(256, p["features"])))


def _open_loop(rt, model_id, X, rate_hz, n_req, seed=0):
    """Drive one tenant with open-loop Poisson arrivals; returns latency
    percentiles and achieved throughput.  Runs inside a started (threaded)
    runtime."""
    rng = np.random.default_rng(seed)
    sched = np.cumsum(rng.exponential(1.0 / rate_hz, size=n_req))
    # GC hygiene: by the later sections this process holds ~10^5 tracked
    # objects (compiled predictors, jax traces), and a gen-2 collection
    # landing inside the timed window is a 30-90 ms pause that shows up
    # as a phantom p99 of whichever section drew the short straw.
    # Collect now and freeze the mature heap so in-window collections
    # only scan the young allocations the run itself makes.
    gc.collect()
    gc.freeze()
    try:
        base = time.perf_counter() + 0.005
        reqs = []
        for i in range(n_req):
            target = base + sched[i]
            while True:
                dt = target - time.perf_counter()
                if dt <= 0:
                    break
                time.sleep(min(dt, 5e-4))
            # arrival stamped at the *scheduled* time: driver lag counts
            # against latency, never against the offered load
            reqs.append(rt.submit(model_id, X[i % len(X)],
                                  arrival_s=target))
        for r in reqs:
            r.wait(timeout=120)
    finally:
        gc.unfreeze()
    lats = np.array([r.latency_ms for r in reqs])
    wall = max(r.done_s for r in reqs) - base
    return {
        "offered_rps": float(rate_hz),
        "achieved_rps": float(n_req / wall),
        "n": int(n_req),
        "p50_ms": float(np.percentile(lats, 50)),
        "p99_ms": float(np.percentile(lats, 99)),
        # steady state: the second half of the run, i.e. after the
        # adaptive controller's ramp (an SLO is a steady-state contract;
        # fixed batching is stationary so its two numbers agree)
        "p99_steady_ms": float(np.percentile(lats[len(lats) // 2:], 99)),
        "mean_ms": float(lats.mean()),
    }


# --------------------------------------------------------------------------- #
# sections
# --------------------------------------------------------------------------- #
def bench_load(p) -> list:
    """Throughput vs latency across the offered-rate ladder."""
    qf = _forest(p)
    records = []
    for rate in p["rates"]:
        pred = core.compile_forest(qf, engine="bitvector")
        rt = ServingRuntime()
        rt.add_model("m", pred, max_batch=64, max_wait_ms=2.0)
        rt.warmup()
        with rt:
            r = _open_loop(rt, "m", np.zeros((64, p["features"])),
                           rate, p["n_req"], seed=int(rate))
        s = rt.summary("m")
        records.append({"section": "load", "model": "m", "mode": "fixed",
                        **r, "mean_batch": s["mean_batch"],
                        "n_batches": s["n_batches"]})
    return records


def bench_adaptive(p) -> list:
    """Adaptive vs fixed batching at one offered load vs the budget.

    The fixed configuration's ``max_wait_ms`` (25 ms) exceeds the 10 ms
    p99 budget, so at a load where batches rarely fill, its oldest
    request waits out the deadline and p99 lands above the budget.  The
    adaptive tenant starts from the *same* knobs but shrinks them as the
    controller observes the violations."""
    qf = _forest(p, seed=1)
    # a rate where the system is calm (cf. the load ladder's low end):
    # the p99 is then governed by the batching wait, which is the knob
    # under test — at saturating rates scheduler-jitter tails dominate
    # and no wait-shrinking can buy them back
    rate = 250.0 if SCALE != "quick" else 500.0
    out = []
    for mode in ("fixed", "adaptive"):
        pred = core.compile_forest(qf, engine="bitvector")
        slo = SLOConfig(target_p99_ms=P99_BUDGET_MS, window=16,
                        min_batch=1, max_batch=64, min_wait_ms=0.0,
                        max_wait_ms=FIXED_WAIT_MS) \
            if mode == "adaptive" else None
        rt = ServingRuntime()
        rt.add_model("m", pred, max_batch=64, max_wait_ms=FIXED_WAIT_MS,
                     slo=slo)
        rt.warmup()
        with rt:
            r = _open_loop(rt, "m", np.zeros((64, p["features"])),
                           rate, p["n_req_adaptive"], seed=7)
        s = rt.summary("m")
        out.append({"section": "adaptive", "model": "m", "mode": mode,
                    **r, "budget_ms": P99_BUDGET_MS,
                    "meets_budget": r["p99_steady_ms"] <= P99_BUDGET_MS,
                    "mean_batch": s["mean_batch"],
                    "effective_max_wait_ms": s["effective_max_wait_ms"],
                    "effective_max_batch": s["effective_max_batch"]})
    return out


def bench_tenants(p, workdir) -> list:
    """Two tenants cold-started from packed artifacts, mixed traffic."""
    qa, qb = _forest(p, seed=2), _forest(p, seed=3)
    fleet = ServingRuntime()
    fleet.add_model("alpha", core.compile_forest(qa, engine="bitvector"),
                    max_batch=64, max_wait_ms=2.0)
    fleet.add_model("beta", core.compile_forest(qb, engine="bitmm"),
                    max_batch=64, max_wait_ms=2.0)
    manifest = fleet.save(workdir)

    rt = ServingRuntime.load(manifest)          # cold start: no recompile
    rt.warmup()
    X = np.random.default_rng(4).normal(size=(64, p["features"]))
    direct = {tid: rt.tenant(tid).predictor.predict(X)
              for tid in rt.model_ids}

    rng = np.random.default_rng(5)
    n_req = p["n_req"]
    rate = max(p["rates"])
    sched = np.cumsum(rng.exponential(1.0 / rate, size=n_req))
    tids = rng.choice(list(rt.model_ids), size=n_req)
    base = time.perf_counter() + 0.005
    reqs = []
    with rt:
        for i in range(n_req):
            target = base + sched[i]
            while True:
                dt = target - time.perf_counter()
                if dt <= 0:
                    break
                time.sleep(min(dt, 5e-4))
            reqs.append((i, tids[i], rt.submit(tids[i], X[i % len(X)],
                                               arrival_s=target)))
        for _, _, r in reqs:
            r.wait(timeout=120)

    bitexact = all(
        np.array_equal(r.result, direct[tid][i % len(X)])
        for i, tid, r in reqs)
    records = []
    for tid in rt.model_ids:
        lats = np.array([r.latency_ms for _, t, r in reqs if t == tid])
        s = rt.summary(tid)
        records.append({
            "section": "tenants", "model": tid, "mode": "cold-start",
            "offered_rps": float(rate) / len(rt.model_ids),
            "achieved_rps": float(len(lats) / (max(
                r.done_s for _, t, r in reqs if t == tid) - base)),
            "n": int(len(lats)),
            "p50_ms": float(np.percentile(lats, 50)),
            "p99_ms": float(np.percentile(lats, 99)),
            "mean_ms": float(lats.mean()),
            "mean_batch": s["mean_batch"],
            "bitexact_vs_predict": bool(bitexact),
        })
    return records


def bench_warmup(p) -> list:
    """First-request latency, cold vs warmed, fused-cascade XLA tier.

    A fresh predictor each way: the cold first request pays the fused
    program's trace + XLA compile; the warmed one only the kernel."""
    qf = _forest(p, seed=6)
    spec = CascadeSpec(stages=p["cascade_stages"],
                       policy=MarginGate(0.8), fused=True)
    x = np.zeros(p["features"])
    first_ms = {}
    for mode in ("cold", "warmed"):
        pred = core.compile_forest(qf, engine="bitvector", cascade=spec)
        rt = ServingRuntime()
        rt.add_model("casc", pred, max_batch=64, max_wait_ms=0.0)
        if mode == "warmed":
            rt.warmup()
        req = rt.submit("casc", x)
        rt.flush()                       # manual mode: latency == compute
        first_ms[mode] = req.latency_ms
    ratio = first_ms["cold"] / first_ms["warmed"]
    return [{
        "section": "warmup", "model": "casc", "mode": mode,
        "first_request_ms": first_ms[mode],
        "cold_over_warm": ratio,
        "n": 1,
    } for mode in ("cold", "warmed")]


def bench_obs(p) -> list:
    """Instrumentation overhead: the same calm open-loop run with the
    full observability layer on (isolated registry, per-request spans,
    retrace polling, a live scrape endpoint) vs ``obs=False``.  The
    calm rate isolates the per-request instrumentation cost — at
    saturating rates the queueing tail hides it entirely."""
    import urllib.request

    from repro.obs import METRIC_CATALOG, MetricsRegistry

    qf = _forest(p, seed=8)
    rate = 250.0 if SCALE != "quick" else 500.0
    results, n_series = {}, 0
    for mode in ("obs-off", "obs-on"):
        on = mode == "obs-on"
        pred = core.compile_forest(qf, engine="bitvector")
        rt = ServingRuntime(obs=MetricsRegistry() if on else False)
        rt.add_model("m", pred, max_batch=64, max_wait_ms=2.0)
        rt.warmup()
        with rt:
            url = rt.serve_metrics().url if on else None
            results[mode] = _open_loop(rt, "m",
                                       np.zeros((64, p["features"])),
                                       rate, p["n_req"], seed=9)
            if on:     # the endpoint was live for the whole run
                with urllib.request.urlopen(url + "/metrics",
                                            timeout=10) as resp:
                    text = resp.read().decode()
                n_series = sum(1 for ln in text.splitlines()
                               if ln and not ln.startswith("#"))
                assert all(name in text for name in METRIC_CATALOG)
    off, on_ = results["obs-off"], results["obs-on"]
    bound_ms = OBS_P99_FACTOR * off["p99_ms"] + OBS_P99_SLACK_MS
    extra = {
        "overhead_p99_ms": on_["p99_ms"] - off["p99_ms"],
        "overhead_p50_ms": on_["p50_ms"] - off["p50_ms"],
        "overhead_mean_ms": on_["mean_ms"] - off["mean_ms"],
        "bound_ms": bound_ms,
        "within_bound": on_["p99_ms"] <= bound_ms,
        "scrape_series": n_series,
    }
    return [{"section": "obs", "model": "m", "mode": mode,
             **results[mode], **(extra if mode == "obs-on" else {})}
            for mode in ("obs-off", "obs-on")]


# --------------------------------------------------------------------------- #
def run(scale: str):
    p = _params(scale)
    suffix = "" if scale == "default" else f"_{scale}"
    cols = ["section", "model", "mode", "n", "offered_rps", "achieved_rps",
            "p50_ms", "p99_ms", "detail"]
    t = Table(f"bench_serving{suffix}", cols)
    records = []
    records += bench_load(p)
    records += bench_adaptive(p)
    with tempfile.TemporaryDirectory(prefix="serving_fleet_") as workdir:
        records += bench_tenants(p, workdir)
    records += bench_warmup(p)
    records += bench_obs(p)
    for r in records:
        if r["section"] == "obs":
            detail = (f"overhead_p99={r['overhead_p99_ms']:+.2f}ms "
                      f"bound={r['bound_ms']:.1f}ms "
                      f"{'WITHIN' if r['within_bound'] else 'EXCEEDS'} "
                      f"series={r['scrape_series']}"
                      if r["mode"] == "obs-on" else "baseline")
        elif r["section"] == "adaptive":
            detail = (f"steady_p99={r['p99_steady_ms']:.2f}ms "
                      f"{'MEETS' if r['meets_budget'] else 'MISSES'} "
                      f"budget={r['budget_ms']:g}ms "
                      f"eff_wait={r['effective_max_wait_ms']:.2f}ms")
        elif r["section"] == "tenants":
            detail = f"bitexact={r['bitexact_vs_predict']}"
        elif r["section"] == "warmup":
            detail = (f"first={r['first_request_ms']:.2f}ms "
                      f"cold/warm={r['cold_over_warm']:.1f}x")
        else:
            detail = f"mean_batch={r['mean_batch']:.1f}"
        t.add(r["section"], r["model"], r["mode"], r["n"],
              f"{r.get('offered_rps', 0.0):.0f}",
              f"{r.get('achieved_rps', 0.0):.0f}",
              f"{r['p50_ms']:.2f}" if "p50_ms" in r else "-",
              f"{r['p99_ms']:.2f}" if "p99_ms" in r else "-",
              detail)
    return t, records


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--json", action="store_true",
                    help="also write BENCH_serving.json at the repo root")
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke: quick sizes, scale-suffixed artifacts")
    args = ap.parse_args(argv)
    scale = "quick" if args.quick else SCALE

    tbl, records = run(scale)
    tbl.print()
    tbl.save()

    adaptive = {r["mode"]: r for r in records
                if r["section"] == "adaptive"}
    warm = next(r for r in records if r["section"] == "warmup"
                and r["mode"] == "warmed")
    a, f = adaptive["adaptive"], adaptive["fixed"]
    verdict = ("adaptive meets the budget, fixed misses"
               if a["meets_budget"] and not f["meets_budget"] else
               "adaptive beats fixed throughput at equal p99"
               if a["achieved_rps"] >= f["achieved_rps"]
               and a["p99_ms"] <= f["p99_ms"] else "INCONCLUSIVE")
    print(f"\nadaptive steady-state p99 {a['p99_steady_ms']:.2f} ms vs "
          f"fixed {f['p99_steady_ms']:.2f} ms "
          f"(budget {P99_BUDGET_MS:g} ms): {verdict}")
    print(f"warmup: cold first request "
          f"{warm['cold_over_warm']:.1f}x slower than warmed "
          f"({warm['first_request_ms']:.2f} ms warmed)")
    obs_on = next(r for r in records if r["section"] == "obs"
                  and r["mode"] == "obs-on")
    print(f"observability: p99 overhead {obs_on['overhead_p99_ms']:+.2f} ms "
          f"(p99 {obs_on['p99_ms']:.2f} ms instrumented, bound "
          f"{obs_on['bound_ms']:.2f} ms = {OBS_P99_FACTOR:g}x off + "
          f"{OBS_P99_SLACK_MS:g} ms): "
          f"{'WITHIN' if obs_on['within_bound'] else 'EXCEEDS'} bound, "
          f"{obs_on['scrape_series']} series scraped live")

    if args.json:
        snapshot = {
            "scale": scale,
            "p99_budget_ms": P99_BUDGET_MS,
            "fixed_wait_ms": FIXED_WAIT_MS,
            "records": records,
            "adaptive_p99_ms": a["p99_ms"],
            "fixed_p99_ms": f["p99_ms"],
            "adaptive_p99_steady_ms": a["p99_steady_ms"],
            "fixed_p99_steady_ms": f["p99_steady_ms"],
            "adaptive_verdict": verdict,
            "warmup_cold_over_warm": warm["cold_over_warm"],
            "tenants_bitexact": all(
                r["bitexact_vs_predict"] for r in records
                if r["section"] == "tenants"),
            "obs_overhead_p99_ms": obs_on["overhead_p99_ms"],
            "obs_overhead_mean_ms": obs_on["overhead_mean_ms"],
            "obs_p99_bound_ms": obs_on["bound_ms"],
            "obs_within_bound": obs_on["within_bound"],
            "obs_scrape_series": obs_on["scrape_series"],
        }
        save_json(f"{tbl.name}_raw", snapshot)
        if scale != "default":      # same source of truth as run()'s suffix
            print(f"scale={scale}: {SNAPSHOT} left untouched")
        else:
            with open(SNAPSHOT, "w") as f2:
                json.dump(snapshot, f2, indent=1, default=float)
            print(f"snapshot written to {SNAPSHOT}")
    if args.quick and not obs_on["within_bound"]:
        # the CI smoke gates on the instrumentation-overhead contract
        print(f"FAILED: instrumented p99 {obs_on['p99_ms']:.2f} ms exceeds "
              f"the bound {obs_on['bound_ms']:.2f} ms", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
