"""TPU roofline for the paper's own workload: forest inference engines
lowered on the production mesh (dry-run — lower + compile + cost analysis,
no execution).

This is the §Perf cell "most representative of the paper's technique":
  * engine=bitvector  — faithful QuickScorer-family port (paper baseline)
  * engine=gemm       — beyond-paper MXU formulation
  * quantization      — float32 vs int16 vs int8 node streams (paper §5)

Serving-shape: a large instance batch sharded over all 256 chips (pure DP —
the forest arrays replicate; they are ≤ a few MB, the paper's whole point
is forests fit near the cores). Per-chip terms come out of
compiled.cost_analysis() exactly like the LM dry-run.

MUST run as its own process (512 host devices):
    PYTHONPATH=src python -m benchmarks.roofline_forest
"""
import os

if __name__ == "__main__":
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import json

import numpy as np


def run() -> list[dict]:
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro import core
    from repro.core.quickscorer import compile_qs, eval_batch as qs_eval
    from repro.core.baselines import compile_gemm, eval_gemm
    from repro.core.quantize import QuantSpec
    from repro.launch.hlo_analysis import (collective_bytes,
                                           normalize_cost_analysis)
    from repro.launch.mesh import make_production_mesh
    from repro.launch.dryrun import PEAK_FLOPS, HBM_BW, ICI_BW

    mesh = make_production_mesh()
    n_chips = 256
    results = []

    CONFIGS = [
        # (tag, n_trees, n_leaves, quant_bits)
        ("paper_1024x64_f32", 1024, 64, None),
        ("paper_1024x64_i16", 1024, 64, 16),
        ("paper_1024x64_i8", 1024, 64, 8),
        ("big_10240x64_f32", 10240, 64, None),
        ("big_10240x64_i16", 10240, 64, 16),
    ]
    BATCH = 1 << 20                      # 4096 instances / chip
    d = 136                              # MSN-shaped

    for tag, T, L, bits in CONFIGS:
        forest = core.random_forest_ir(T, L, d, n_classes=1, seed=0)
        if bits:
            forest = core.quantize_forest(forest, spec=QuantSpec(bits=bits))
        for engine in ("bitvector", "gemm"):
            if engine == "bitvector":
                compiled_f = compile_qs(forest)
                fn = lambda X, c=compiled_f: qs_eval(c, X)
            else:
                cd = jnp.bfloat16 if bits else jnp.float32
                compiled_f = compile_gemm(forest, compute_dtype=cd)
                fn = lambda X, c=compiled_f: eval_gemm(c, X)
            in_dtype = (jnp.int16 if bits == 16 else
                        jnp.int8 if bits == 8 else jnp.float32)
            # integer inputs flow through the same comparison graph
            xs = jax.ShapeDtypeStruct((BATCH, d), in_dtype)
            xshard = NamedSharding(mesh, P(("data", "model"), None))
            with mesh:
                lowered = jax.jit(
                    fn, in_shardings=xshard,
                    out_shardings=NamedSharding(
                        mesh, P(("data", "model"), None))).lower(xs)
                comp = lowered.compile()
            cost = normalize_cost_analysis(comp.cost_analysis())
            coll = collective_bytes(comp.as_text())
            flops = float(cost.get("flops", 0.0))
            byt = float(cost.get("bytes accessed", 0.0))
            terms = {
                "compute_s": flops / PEAK_FLOPS,
                "memory_s": byt / HBM_BW,
                "collective_s": coll.link_bytes / ICI_BW,
            }
            dom = max(terms, key=terms.get)
            bound = max(terms.values())
            per_inst_ns = bound / (BATCH / n_chips) * 1e9
            results.append({
                "config": tag, "engine": engine,
                "flops_per_chip": flops, "bytes_per_chip": byt,
                "collective_bytes": coll.link_bytes,
                **{k: round(v, 6) for k, v in terms.items()},
                "dominant": dom,
                "ns_per_instance_roofline": round(per_inst_ns, 3),
            })
            print(f"[{tag:22s}] {engine:9s} dom={dom:12s} "
                  f"c={terms['compute_s']*1e3:8.3f}ms "
                  f"m={terms['memory_s']*1e3:8.3f}ms "
                  f"x={terms['collective_s']*1e3:8.3f}ms "
                  f"→ {per_inst_ns:8.2f} ns/inst", flush=True)

    # ---- latency mode: tree-sharding vs data-parallel ------------------ #
    # Small-batch latency serving (the paper's IoT regime writ large): with
    # B ≪ chips × useful-batch, pure DP leaves chips idle. Sharding TREES
    # across the mesh (ensemble additivity → partial scores + one (B, C)
    # all-reduce) engages every chip at any batch size — the forest-world
    # analogue of expert parallelism.
    B_LAT, T_LAT = 4096, 10240
    forest = core.random_forest_ir(T_LAT, 64, d, n_classes=1, seed=0)
    cqs = compile_qs(forest)
    arrs = dict(feat=cqs.feat, thr=cqs.thr, valid=cqs.valid,
                masks=cqs.masks, init_idx=cqs.init_idx,
                leaf_val=cqs.leaf_val)
    for mode in ("dp", "treeshard"):
        if mode == "dp":
            xsh = NamedSharding(mesh, P(("data", "model"), None))
            tree_sh = {k: NamedSharding(mesh, P(*([None] * v.ndim)))
                       for k, v in arrs.items()}
        else:
            xsh = NamedSharding(mesh, P())           # X replicated
            tree_sh = {k: NamedSharding(
                mesh, P(("data", "model"), *([None] * (v.ndim - 1))))
                for k, v in arrs.items()}

        def fn(X, feat, thr, valid, masks, init_idx, leaf_val, c=cqs):
            from dataclasses import replace as drep
            qs2 = drep(c, feat=feat, thr=thr, valid=valid, masks=masks,
                       init_idx=init_idx, leaf_val=leaf_val, forest=None)
            return qs_eval(qs2, X)

        xs = jax.ShapeDtypeStruct((B_LAT, d), jnp.float32)
        a_specs = {k: jax.ShapeDtypeStruct(v.shape, v.dtype)
                   for k, v in arrs.items()}
        with mesh:
            comp = jax.jit(fn, in_shardings=(
                xsh, tree_sh["feat"], tree_sh["thr"], tree_sh["valid"],
                tree_sh["masks"], tree_sh["init_idx"], tree_sh["leaf_val"]),
                out_shardings=NamedSharding(mesh, P())).lower(
                xs, a_specs["feat"], a_specs["thr"], a_specs["valid"],
                a_specs["masks"], a_specs["init_idx"],
                a_specs["leaf_val"]).compile()
        cost = normalize_cost_analysis(comp.cost_analysis())
        coll = collective_bytes(comp.as_text())
        terms = {
            "compute_s": float(cost.get("flops", 0)) / PEAK_FLOPS,
            "memory_s": float(cost.get("bytes accessed", 0)) / HBM_BW,
            "collective_s": coll.link_bytes / ICI_BW,
        }
        bound = max(terms.values())
        results.append({
            "config": f"latency_b{B_LAT}_t{T_LAT}", "engine": f"bitvector_{mode}",
            **{k: round(v, 6) for k, v in terms.items()},
            "dominant": max(terms, key=terms.get),
            "us_batch_latency_roofline": round(bound * 1e6, 2),
        })
        print(f"[latency_b{B_LAT:6d}] {mode:10s} "
              f"c={terms['compute_s']*1e3:7.3f}ms "
              f"m={terms['memory_s']*1e3:7.3f}ms "
              f"x={terms['collective_s']*1e3:7.3f}ms "
              f"→ batch latency {bound*1e6:8.1f} µs", flush=True)

    # ---- Pallas-kernel HBM projection (§Perf forest iteration 2) ------- #
    # The XLA bitvector engine streams its (B,T,N) cond and (B,T,N,W)
    # select intermediates through HBM (fusion boundaries). The Pallas
    # kernel (kernels/quickscorer_kernel.py) keeps the whole
    # (block_b × block_t) tile in VMEM, so HBM traffic collapses to:
    #   X read per tree-tile revisit + forest stream per batch-tile revisit
    #   + output accumulator revisits.
    # Compiled-for-TPU numbers are unavailable on this container (interpret
    # mode only); this projection uses the same BlockSpec arithmetic the
    # kernel declares, and is validated against the kernel's actual block
    # shapes in tests/test_kernels.py.
    BLOCK_B, BLOCK_T = 512, 128
    for tag, T, L, bits in CONFIGS:
        W = (L + 31) // 32
        thr_b = {None: 4, 16: 2, 8: 1}[bits]
        N = L - 1
        b_chip = BATCH // n_chips
        nb, nt = b_chip // BLOCK_B, max(T // BLOCK_T, 1)
        x_bytes = b_chip * d * 4 * nt                # X re-read per tree tile
        forest_bytes = (T * N * (4 + thr_b + 4 * W)  # feat+thr+masks
                        + T * (4 * W) + T * L * 4) * nb
        out_bytes = b_chip * 1 * 4 * nt * 2          # accumulator revisits
        hbm = x_bytes + forest_bytes + out_bytes
        vmem = (BLOCK_B * d * 4 + BLOCK_T * N * (4 + thr_b + 4 * W)
                + BLOCK_T * (4 * W + L * 4) + BLOCK_B * 4)
        mem_s = hbm / HBM_BW
        comp = next(r for r in results
                    if r["config"] == tag and r["engine"] == "bitvector")
        comp_s = comp["compute_s"]
        bound = max(mem_s, comp_s)
        results.append({
            "config": tag, "engine": "bitvector+pallas(projected)",
            "bytes_per_chip": hbm, "vmem_per_block": vmem,
            "compute_s": comp_s, "memory_s": round(mem_s, 6),
            "collective_s": 0.0,
            "dominant": "memory_s" if mem_s > comp_s else "compute_s",
            "ns_per_instance_roofline": round(
                bound / (BATCH / n_chips) * 1e9, 3),
        })
        print(f"[{tag:22s}] pallas-proj dom="
              f"{'memory' if mem_s > comp_s else 'compute':9s} "
              f"m={mem_s*1e3:8.3f}ms vmem={vmem/1e6:.2f}MB "
              f"→ {bound / (BATCH / n_chips) * 1e9:8.2f} ns/inst", flush=True)

    out_dir = os.path.join(os.path.dirname(__file__), "..", "experiments",
                           "bench")
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, "roofline_forest.json"), "w") as f:
        json.dump(results, f, indent=1)
    return results


if __name__ == "__main__":
    run()
