"""Cascade A/B harness: confidence-gated staged evaluation vs the full
forest, per engine, on a real classification dataset.

    PYTHONPATH=src python -m benchmarks.bench_cascade            # table
    PYTHONPATH=src python -m benchmarks.bench_cascade --json     # + snapshot

For each (dataset, engine) pair a random forest is trained, quantized,
and served three ways: the plain engine over all trees, the staged
cascade (host loop between stages), and the fused cascade (one jitted
computation, ``cascade/fused.py``) — both cascade variants share one
calibration (``repro.cascade``, threshold picked on held-out rows under
the 0.5 pp accuracy floor), so their rows differ only in execution.
Reported per row:

  * ``variant``       — ``staged`` or ``fused``;
  * ``host_syncs``    — device→host syncs per batch (staged: one per
    stage; fused: 1);
  * ``speedup_wall``  — full-forest wall-clock / cascade wall-clock;
  * ``speedup_trees`` — n_trees / mean trees evaluated per row (the
    device-independent work reduction);
  * ``acc_drop_pp``   — accuracy delta at the calibrated threshold.

The CSV (experiments/bench/), the raw JSON, and the repo-root
``BENCH_cascade.json`` snapshot all come from the **same** run's records
(PR-1's artifact-consistency rule: derived artifacts can never contradict
the raw data).  Non-default ``REPRO_BENCH_SCALE`` runs write
scale-suffixed artifacts (``bench_cascade_quick.*``) and leave the
canonical default-scale set — including the repo-root snapshot —
untouched.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

import numpy as np

from repro import core
from repro.cascade import calibrate, CascadePredictor, CascadeSpec, \
    MarginGate
from repro.data import datasets
from repro.trees.random_forest import RandomForest, RandomForestConfig

from .common import SCALE, Table, save_json, scale_pick, time_predict, \
    us_per_instance

REPO_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
SNAPSHOT = os.path.join(REPO_ROOT, "BENCH_cascade.json")


def cases():
    # (dataset, n_trees, max_leaves, stages)
    return scale_pick(
        [("magic", 128, 32, (8, 32))],
        [("magic", 256, 32, (16, 64)), ("mnist", 192, 32, (16, 64))],
        [("magic", 512, 64, (16, 64, 256)),
         ("mnist", 512, 64, (16, 64, 256)),
         ("eeg", 512, 64, (16, 64, 256))],
    )


def engines():
    return scale_pick(["bitvector"], ["bitvector", "bitmm"],
                      ["bitvector", "bitmm", "gemm"])


def _bench_case(dataset, n_trees, max_leaves, stages, engine,
                repeats, floor_pp, seed=0):
    ds = datasets.load(dataset)
    rf = RandomForest(RandomForestConfig(
        n_trees=n_trees, max_leaves=max_leaves, seed=seed)).fit(
        ds.X_train, ds.y_train)
    qf = core.quantize_forest(core.from_random_forest(rf), ds.X_train)

    # held-out calibration rows must be disjoint from the timed test rows
    n_cal = len(ds.X_test) // 2
    X_cal, y_cal = ds.X_test[:n_cal], ds.y_test[:n_cal]
    X_test, y_test = ds.X_test[n_cal:], ds.y_test[n_cal:]

    full = core.compile_forest(qf, engine=engine)
    casc = core.compile_forest(qf, engine=engine,
                               cascade=CascadeSpec(stages=stages))
    cal = calibrate(casc, X_cal, y_cal, floor_pp=floor_pp)
    casc.set_policy(cal.policy)
    fused = core.compile_forest(qf, engine=engine,
                                cascade=CascadeSpec(stages=stages,
                                                    fused=True))
    fused.set_policy(cal.policy)         # one calibration, two executions

    us_full = us_per_instance(
        time_predict(lambda: full.predict(X_test), repeats=repeats),
        len(X_test))
    acc_full = float((full.predict_class(X_test) == y_test).mean())

    records = []
    for variant, pred in (("staged", casc), ("fused", fused)):
        pred.reset_exit_stats()
        us_casc = us_per_instance(
            time_predict(lambda: pred.predict(X_test), repeats=repeats),
            len(X_test))
        acc_casc = float((pred.predict_class(X_test) == y_test).mean())
        mean_trees = pred.mean_trees_evaluated
        records.append({
            "dataset": dataset, "engine": engine, "variant": variant,
            "trees": n_trees, "leaves": max_leaves,
            "stages": list(pred.stages), "policy": pred.policy.tag(),
            "host_syncs": int(pred.host_syncs),
            "n_test": int(len(X_test)),
            "us_full": us_full, "us_cascade": us_casc,
            "speedup_wall": us_full / us_casc,
            "mean_trees": mean_trees,
            "speedup_trees": n_trees / mean_trees,
            "exit_fractions": pred.exit_fractions.tolist(),
            "acc_full": acc_full, "acc_cascade": acc_casc,
            "acc_drop_pp": (acc_full - acc_casc) * 100.0,
        })
    # identical decisions by construction (shared jitted gate) — catch
    # any drift between the two execution schemes right in the bench
    s, f = records
    if s["exit_fractions"] != f["exit_fractions"]:
        raise AssertionError(
            f"staged/fused exit fractions diverged on {dataset}/{engine}: "
            f"{s['exit_fractions']} vs {f['exit_fractions']}")
    return records


def run(repeats: int = 5, floor_pp: float = 0.5):
    """Non-default scales get scale-suffixed artifacts (and leave the
    repo-root snapshot untouched, see ``main``): a quick-scale
    validation run must never clobber the canonical default-scale CSV —
    the CSV/raw/snapshot triplet always comes from one run (the PR-1
    artifact-consistency rule, enforced like ``bench_engines``'s subset
    rename)."""
    suffix = "" if SCALE == "default" else f"_{SCALE}"
    cols = ["dataset", "engine", "variant", "trees", "stages", "policy",
            "host_syncs", "full_us", "casc_us", "speedup_wall",
            "mean_trees", "speedup_trees", "acc_full", "acc_casc",
            "drop_pp"]
    t = Table(f"bench_cascade{suffix}", cols)
    records = []
    for (dataset, n_trees, max_leaves, stages) in cases():
        for engine in engines():
            for r in _bench_case(dataset, n_trees, max_leaves, stages,
                                 engine, repeats, floor_pp):
                records.append(r)
                t.add(r["dataset"], r["engine"], r["variant"], r["trees"],
                      "/".join(map(str, r["stages"])), r["policy"],
                      r["host_syncs"],
                      f"{r['us_full']:.1f}", f"{r['us_cascade']:.1f}",
                      f"{r['speedup_wall']:.2f}x",
                      f"{r['mean_trees']:.1f}",
                      f"{r['speedup_trees']:.2f}x",
                      f"{r['acc_full']:.4f}", f"{r['acc_cascade']:.4f}",
                      f"{r['acc_drop_pp']:.2f}")
    return t, records


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--json", action="store_true",
                    help="also write BENCH_cascade.json at the repo root")
    ap.add_argument("--repeats", type=int, default=5)
    ap.add_argument("--floor-pp", type=float, default=0.5,
                    help="calibration accuracy floor (percentage points)")
    args = ap.parse_args(argv)

    tbl, records = run(repeats=args.repeats, floor_pp=args.floor_pp)
    tbl.print()
    tbl.save()
    ok = [r for r in records if r["acc_drop_pp"] <= args.floor_pp]
    best = max(ok, key=lambda r: r["speedup_wall"], default=None)
    if best is not None:
        print(f"\nbest cascade (<= {args.floor_pp:g} pp drop): "
              f"{best['dataset']}/{best['engine']}/{best['variant']} — "
              f"{best['speedup_trees']:.2f}x fewer "
              f"trees, {best['speedup_wall']:.2f}x wall-clock, "
              f"{best['acc_drop_pp']:.2f} pp drop")
    if args.json:
        snapshot = {
            "scale": SCALE,
            "floor_pp": args.floor_pp,
            "records": records,
            "best_speedup_trees": best["speedup_trees"] if best else None,
            "best_speedup_wall": best["speedup_wall"] if best else None,
            "best_pair": (f"{best['dataset']}/{best['engine']}/"
                          f"{best['variant']}" if best else None),
        }
        save_json(f"{tbl.name}_raw", snapshot)
        if SCALE != "default":      # same source of truth as run()'s suffix
            print(f"scale={SCALE}: {SNAPSHOT} left untouched")
        else:
            with open(SNAPSHOT, "w") as f:
                json.dump(snapshot, f, indent=1, default=float)
            print(f"snapshot written to {SNAPSHOT}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
