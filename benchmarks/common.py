"""Shared benchmark machinery: timing, table formatting, CSV output.

Scale control: REPRO_BENCH_SCALE=quick|default|full. `quick` is CI-sized,
`full` approaches the paper's sizes (1024-tree forests, 20k-tree GBTs) and
takes hours on the CPU container. All benches print their scale.

Measurement discipline: wall-clock on this container is a *relative*
algorithm comparison on CPU-executed XLA programs (the paper's absolute
numbers are ARM-specific); TPU projections come from the dry-run roofline
(benchmarks/roofline_forest.py), never from CPU wall-clock.
"""
from __future__ import annotations

import csv
import json
import os
import time
from dataclasses import dataclass, field
from typing import Callable, Optional

import numpy as np

SCALE = os.environ.get("REPRO_BENCH_SCALE", "default")

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                           "bench")


def scale_pick(quick, default, full):
    return {"quick": quick, "default": default, "full": full}[SCALE]


def sync(result):
    """Block until the device work backing ``result`` (any array /
    pytree) has finished, and pass it through.  jax dispatch is async:
    without this, a timed loop over a fn that returns device arrays
    (e.g. the fused cascade before its host conversion) stops the clock
    before the computation does.  Numpy results pass through untouched
    (predictors that already convert on the host have synced by
    definition)."""
    import jax
    return jax.block_until_ready(result)


def time_predict(fn: Callable[[], object], *, warmup: int = 2,
                 repeats: int = 5) -> float:
    """Median wall-clock seconds of fn() after warmup.  Every call is
    wrapped in ``sync`` so async device dispatch can't understate the
    measurement — all bench loops time through here."""
    for _ in range(warmup):
        sync(fn())
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        sync(fn())
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


@dataclass
class Table:
    name: str
    columns: list
    rows: list = field(default_factory=list)

    def add(self, *row):
        self.rows.append(list(row))

    def print(self):
        widths = [max(len(str(c)), *(len(str(r[i])) for r in self.rows))
                  if self.rows else len(str(c))
                  for i, c in enumerate(self.columns)]
        line = "  ".join(str(c).ljust(w) for c, w in zip(self.columns,
                                                         widths))
        print(f"\n== {self.name} ==")
        print(line)
        print("-" * len(line))
        for r in self.rows:
            print("  ".join(str(v).ljust(w) for v, w in zip(r, widths)))

    def save(self):
        os.makedirs(RESULTS_DIR, exist_ok=True)
        path = os.path.join(RESULTS_DIR, f"{self.name}.csv")
        with open(path, "w", newline="") as f:
            w = csv.writer(f)
            w.writerow(self.columns)
            w.writerows(self.rows)
        return path


def us_per_instance(seconds: float, batch: int) -> float:
    return seconds / batch * 1e6


def save_json(name: str, obj) -> str:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, f"{name}.json")
    with open(path, "w") as f:
        json.dump(obj, f, indent=1, default=float)
    return path
