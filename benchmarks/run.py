"""Benchmark driver: one function per paper table/figure + the TPU
roofline benches + the engine A/B harness.

    PYTHONPATH=src python -m benchmarks.run            # default scale
    REPRO_BENCH_SCALE=quick  python -m benchmarks.run  # CI-sized
    REPRO_BENCH_SCALE=full   python -m benchmarks.run  # paper-sized (hours)
    PYTHONPATH=src python -m benchmarks.run --json     # + BENCH_engines.json
    PYTHONPATH=src python -m benchmarks.run --only table4_merging

``--json`` makes the engine bench write ``BENCH_engines.json``, the
cascade bench ``BENCH_cascade.json``, the optimizer bench
``BENCH_optim.json``, and the autotune bench ``BENCH_autotune.json``
perf snapshots at the repo root, so successive PRs accumulate a
trajectory.  ``--only <name>`` runs a single bench — the
full sweep is far too slow when iterating on one table.

The forest-roofline bench needs 512 placeholder devices, so it runs as a
subprocess (this process keeps the single real CPU device).
"""
from __future__ import annotations

import argparse
import os
import subprocess
import sys
import time

from .common import SCALE


def _run_roofline() -> None:
    env = dict(os.environ)
    env["PYTHONPATH"] = env.get("PYTHONPATH", "src")
    r = subprocess.run(
        [sys.executable, "-m", "benchmarks.roofline_forest"],
        env=env, cwd=os.path.join(os.path.dirname(__file__), ".."))
    if r.returncode != 0:
        print("[bench] roofline_forest FAILED", file=sys.stderr)
        sys.exit(1)


def _benches(json_flag: bool) -> dict:
    """name → zero-arg runner, in sweep order.  Lazy imports so
    ``--only x`` never pays for (or breaks on) the other benches."""
    def table(name):
        def run():
            import importlib
            importlib.import_module(f"benchmarks.{name}").main()
        return run

    def with_json(name):
        def run():
            import importlib
            importlib.import_module(f"benchmarks.{name}").main(
                ["--json"] if json_flag else [])
        return run

    return {
        "table2_ranking": table("table2_ranking"),
        "table3_quant_accuracy": table("table3_quant_accuracy"),
        "table4_merging": table("table4_merging"),
        "table5_classification": table("table5_classification"),
        "fig1_speedup": table("fig1_speedup"),
        "bench_coldstart": table("bench_coldstart"),
        "bench_engines": with_json("bench_engines"),
        "bench_cascade": with_json("bench_cascade"),
        "bench_optim": with_json("bench_optim"),
        "bench_serving": with_json("bench_serving"),
        "bench_autotune": with_json("bench_autotune"),
        "roofline_forest": _run_roofline,
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", action="store_true",
                    help="write the BENCH_*.json perf snapshots")
    ap.add_argument("--only", default=None,
                    help="run a single bench by name")
    args = ap.parse_args()

    benches = _benches(args.json)
    if args.only is not None and args.only not in benches:
        ap.error(f"unknown bench {args.only!r}; choose from "
                 f"{sorted(benches)}")

    t0 = time.time()
    print(f"[bench] scale={SCALE}")
    selected = {args.only: benches[args.only]} if args.only else benches
    for name, run in selected.items():
        t = time.time()
        print(f"\n[bench] running {name} ...", flush=True)
        run()
        print(f"[bench] {name} done in {time.time()-t:.1f}s", flush=True)

    print(f"\n[bench] all done in {time.time()-t0:.1f}s; CSVs in "
          "experiments/bench/")


if __name__ == "__main__":
    main()
