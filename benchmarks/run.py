"""Benchmark driver: one function per paper table/figure + the TPU
roofline benches + the engine A/B harness.

    PYTHONPATH=src python -m benchmarks.run            # default scale
    REPRO_BENCH_SCALE=quick  python -m benchmarks.run  # CI-sized
    REPRO_BENCH_SCALE=full   python -m benchmarks.run  # paper-sized (hours)
    PYTHONPATH=src python -m benchmarks.run --json     # + BENCH_engines.json

``--json`` makes the engine bench write ``BENCH_engines.json`` and the
cascade bench ``BENCH_cascade.json`` perf snapshots at the repo root, so
successive PRs accumulate a trajectory.

The forest-roofline bench needs 512 placeholder devices, so it runs as a
subprocess (this process keeps the single real CPU device).
"""
from __future__ import annotations

import argparse
import os
import subprocess
import sys
import time

from .common import SCALE


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", action="store_true",
                    help="write the BENCH_*.json perf snapshots")
    args = ap.parse_args()

    t0 = time.time()
    print(f"[bench] scale={SCALE}")

    from . import (bench_cascade, bench_coldstart, bench_engines,
                   fig1_speedup, table2_ranking, table3_quant_accuracy,
                   table4_merging, table5_classification)

    for name, mod in [("table2_ranking", table2_ranking),
                      ("table3_quant_accuracy", table3_quant_accuracy),
                      ("table4_merging", table4_merging),
                      ("table5_classification", table5_classification),
                      ("fig1_speedup", fig1_speedup),
                      ("bench_coldstart", bench_coldstart)]:
        t = time.time()
        print(f"\n[bench] running {name} ...", flush=True)
        mod.main()
        print(f"[bench] {name} done in {time.time()-t:.1f}s", flush=True)

    t = time.time()
    print("\n[bench] running bench_engines ...", flush=True)
    bench_engines.main(["--json"] if args.json else [])
    print(f"[bench] bench_engines done in {time.time()-t:.1f}s", flush=True)

    t = time.time()
    print("\n[bench] running bench_cascade ...", flush=True)
    bench_cascade.main(["--json"] if args.json else [])
    print(f"[bench] bench_cascade done in {time.time()-t:.1f}s", flush=True)

    # roofline (512-device dry-run) in a subprocess
    print("\n[bench] running roofline_forest (subprocess) ...", flush=True)
    env = dict(os.environ)
    env["PYTHONPATH"] = env.get("PYTHONPATH", "src")
    r = subprocess.run(
        [sys.executable, "-m", "benchmarks.roofline_forest"],
        env=env, cwd=os.path.join(os.path.dirname(__file__), ".."))
    if r.returncode != 0:
        print("[bench] roofline_forest FAILED", file=sys.stderr)
        sys.exit(1)

    print(f"\n[bench] all done in {time.time()-t0:.1f}s; CSVs in "
          "experiments/bench/")


if __name__ == "__main__":
    main()
