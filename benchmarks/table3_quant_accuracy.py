"""Paper Table 3: accuracy of the four (split × leaf) quantization combos
for an RF on the 5 classification datasets.

Paper scale: 1024 trees × 64 leaves. Default scale here trains 128×32
(REPRO_BENCH_SCALE=full for 1024×64). Absolute accuracies differ from the
paper (synthetic data stand-ins, DESIGN.md §5); the *claim under test* is
the quantization deltas: ≈0 everywhere except EEG-like heavy-tailed
features, where split-quantization costs points.

Two integer-execution rows ride along (docs/QUANT.md): `int16/int16-acc`
(same quantized forest, pure-integer accumulation — bit-exact vs
`int16/int16` by construction, so its column must match exactly) and
`flint` (f32 comparisons rekeyed as monotone int32 — bit-identical to
`float/float` by construction). Any delta in those rows is a bug, not a
trade-off.
"""
from __future__ import annotations

import numpy as np

from repro import core
from repro.core.pipeline import CompilePlan, compile_plan
from repro.core.quantize import QuantSpec
from repro.data import datasets
from repro.trees.random_forest import RandomForest, RandomForestConfig

from .common import Table, scale_pick

DATASETS = ["adult", "eeg", "fashion", "magic", "mnist"]

COMBOS = [
    ("float/float", None),
    ("float/int16", QuantSpec(quantize_splits=False)),
    ("int16/float", QuantSpec(quantize_leaves=False)),
    ("int16/int16", QuantSpec()),
    ("int16/int16-acc", QuantSpec(int_accum=True)),
    ("flint", "flint"),
]


def run() -> Table:
    n_trees = scale_pick(64, 128, 1024)
    n_leaves = scale_pick(32, 64, 64)     # paper Table 3 is 64-leaf trees
    n_samples = scale_pick(1500, 3000, 8000)

    t = Table("table3_quant_accuracy",
              ["dataset"] + [c for c, _ in COMBOS] + ["max_delta_pp"])
    for name in DATASETS:
        ds = datasets.load(name, n=n_samples)
        rf = RandomForest(RandomForestConfig(
            n_trees=n_trees, max_leaves=n_leaves, seed=0)).fit(
            ds.X_train, ds.y_train)
        forest = core.from_random_forest(rf)
        accs = []
        for _, spec in COMBOS:
            if spec == "flint":
                pred = compile_plan(forest, CompilePlan(engine="bitvector",
                                                        flint=True))
            else:
                f = forest if spec is None else core.quantize_forest(
                    forest, ds.X_train, spec=spec)
                pred = core.compile_forest(f, engine="bitvector")
            acc = (pred.predict_class(ds.X_test) == ds.y_test).mean()
            accs.append(acc)
        delta = (max(accs) - min(accs)) * 100
        t.add(name, *[f"{a*100:.2f}%" for a in accs], f"{delta:.2f}")
    return t


def main():
    tbl = run()
    tbl.print()
    tbl.save()


if __name__ == "__main__":
    main()
