"""Paper Figure 1: average speedup over the float NATIVE baseline as a
function of tree count, float (top) and quantized (bottom) variants.

Averaged over {32, 64} leaves like the paper (datasets collapse to feature
count for runtime, so the sweep uses the MSN-like 136-feature shape).
"""
from __future__ import annotations

import numpy as np

from repro import core

from .common import Table, save_json, scale_pick, time_predict, \
    us_per_instance

ENGINES = ["rapidscorer", "bitvector", "native", "unrolled", "gemm"]


UNROLL_CAP = 1000    # see table2_ranking.UNROLL_CAP


def run() -> Table:
    tree_counts = scale_pick([100, 400], [100, 400, 1600],
                             [100, 200, 400, 800, 1600, 3200])
    leaves = scale_pick([32], [32, 64], [32, 64])
    batch = scale_pick(256, 512, 2048)
    d = 136

    rng = np.random.default_rng(0)
    X = rng.normal(0, 1, size=(batch, d))
    t = Table("fig1_speedup",
              ["trees"] + [f"{e}" for e in ENGINES] +
              [f"q_{e}" for e in ENGINES])
    raw = {}
    for T in tree_counts:
        sums = {k: [] for k in t.columns[1:]}
        for L in leaves:
            forest = core.random_forest_ir(T, L, d, seed=T + L)
            qforest = core.quantize_forest(forest)
            # float NATIVE is the baseline for everything — time it first
            na_pred = core.compile_forest(forest, engine="native")
            na = us_per_instance(
                time_predict(lambda: na_pred.predict(X)), batch)
            for quant, f in ((False, forest), (True, qforest)):
                for e in ENGINES:
                    if e == "unrolled" and T > UNROLL_CAP:
                        continue
                    if not quant and e == "native":
                        us = na
                    else:
                        pred = core.compile_forest(f, engine=e)
                        us = us_per_instance(
                            time_predict(lambda: pred.predict(X)), batch)
                    key = f"q_{e}" if quant else e
                    sums[key].append((na, us))
        row = [T]
        for k in t.columns[1:]:
            if not sums[k]:
                row.append("-")          # unrolled beyond compile cap
                continue
            sp = np.mean([n / u for n, u in sums[k]])
            row.append(f"{sp:.2f}x")
            raw.setdefault(k, []).append(sp)
        t.add(*row)
    save_json("fig1_raw", {"trees": tree_counts, "speedups": raw})
    return t


def main():
    tbl = run()
    tbl.print()
    tbl.save()


if __name__ == "__main__":
    main()
