"""Optimizer middle-end A/B harness: what does -O2 buy per engine?

    PYTHONPATH=src python -m benchmarks.bench_optim            # table
    PYTHONPATH=src python -m benchmarks.bench_optim --json     # + snapshot

For each workload (trained forests on real datasets + one synthetic
random-structure forest, quantized like the serving path), the bench
reports:

  * per-pass node / unique-threshold / L / d reduction at ``-O2``
    (``repro.optim`` PassStats — the structural effect, docs/OPTIM.md);
  * per-engine wall-clock at ``-O0`` vs ``-O2`` on the same batch and
    the resulting speedup ratio (the runtime effect).

``--json`` writes ``BENCH_optim.json`` at the repo root (a perf
trajectory for future PRs) plus the raw records under
``experiments/bench/``.  Honest-measurement note: trained CART forests
contain no dominated splits by construction, so their -O2 win comes
from threshold canonicalization, padding shrink, and unused-feature
drops; the synthetic random-structure forest shows the dominated-split
collapse at full strength.  Engines are the registry's XLA set
(``engine_select.default_engines``).
"""
from __future__ import annotations

import argparse
import json
import os
import sys

import numpy as np

from repro import core, optim
from repro.core import engine_select
from repro.core.pipeline import CompilePlan, compile_plan

from .common import SCALE, Table, save_json, scale_pick, time_predict, \
    us_per_instance

REPO_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
SNAPSHOT = os.path.join(REPO_ROOT, "BENCH_optim.json")


def workloads():
    """(name, forest, X_calib, batch) per scale.  Forests arrive already
    quantized — the optimizer's collapse claims are about the fixed-point
    grid (paper Table 4), and quantized is the serving configuration."""
    from repro.data import datasets
    from repro.trees.random_forest import RandomForest, RandomForestConfig

    configs = scale_pick(
        [("magic", 32, 32, 1000, 256, 16)],
        [("magic", 64, 32, 2000, 256, 16),
         ("magic@q8", 64, 32, 2000, 256, 8),
         ("mnist", 64, 32, 2000, 256, 16)],
        [("magic", 256, 64, 8000, 1024, 16),
         ("magic@q8", 256, 64, 8000, 1024, 8),
         ("mnist", 256, 64, 8000, 1024, 16),
         ("eeg", 256, 64, 8000, 1024, 16)],
    )
    out = []
    for name, T, L, n, B, bits in configs:
        ds = datasets.load(name.split("@")[0], n=n)
        rf = RandomForest(RandomForestConfig(
            n_trees=T, max_leaves=L, seed=0)).fit(ds.X_train, ds.y_train)
        forest = core.from_random_forest(rf)
        # 8-bit variants are where the paper's threshold collapse (and so
        # dedup_thresholds / merge_equivalent_leaves) bites on *trained*
        # forests; at 16 bits trained splits rarely land on one grid point
        qf = core.quantize_forest(forest, ds.X_train,
                                  core.QuantSpec(bits=bits))
        out.append((name, qf, ds.X_train, B))
    # synthetic random-structure forest: dominated splits exist here (a
    # random tree re-splits features arbitrarily along a path), so the
    # structural passes show their full-strength effect
    T, L, d, B = scale_pick((64, 32, 32, 256), (128, 32, 32, 256),
                            (512, 64, 64, 1024))
    synth = core.quantize_forest(core.random_forest_ir(T, L, d, seed=7),
                                 None)
    out.append(("synthetic", synth, None, B))
    return out


def run(repeats: int = 5, opt_level=2):
    """Non-default scales get scale-suffixed artifacts (and leave the
    repo-root snapshot untouched, see ``main``): a quick-scale run must
    never replace the canonical default-scale perf trajectory (the PR-1
    artifact-consistency rule, same guard as ``bench_cascade``)."""
    suffix = "" if SCALE == "default" else f"_{SCALE}"
    engines = engine_select.default_engines()
    t = Table(f"bench_optim{suffix}",
              ["workload", "engine", "O0_us", f"O{opt_level}_us",
               "speedup", "nodes", "thr", "L", "d"])
    records = []
    for name, qf, X_calib, B in workloads():
        res = optim.optimize(qf, opt_level,
                             ctx={"X_calib": X_calib}, verify=True)
        b = res.stats[0].before
        a = res.stats[-1].after
        print(f"\n[{name}] {res.describe()}")
        for s in res.stats:
            print(f"  {s.name:24s} {s.detail()}")
        rng = np.random.default_rng(0)
        X = rng.normal(0, 1.0, size=(B, qf.n_features_in))
        eng_rec = {}
        for e in engines:
            spec = core.registry.by_tune_name(e)
            us = {}
            for lvl in (None, opt_level):
                pred = compile_plan(qf, CompilePlan(
                    engine=spec.name, backend=spec.backend, opt=lvl))
                us[lvl] = us_per_instance(
                    time_predict(lambda: pred.predict(X),
                                 repeats=repeats), B)
            ratio = us[None] / us[opt_level]
            t.add(name, e, f"{us[None]:.1f}", f"{us[opt_level]:.1f}",
                  f"{ratio:.2f}x",
                  f"{b.n_nodes}→{a.n_nodes}",
                  f"{b.n_unique_splits}→{a.n_unique_splits}",
                  f"{b.n_leaves}→{a.n_leaves}",
                  f"{b.n_features}→{a.n_features}")
            eng_rec[e] = {"o0_us": us[None], "opt_us": us[opt_level],
                          "speedup": ratio}
        records.append({
            "workload": name,
            "shape": {"trees": b.n_trees, "leaves": b.n_leaves,
                      "features": b.n_features, "batch": B},
            "opt_level": opt_level,
            "verified": res.verified,
            "passes": [{"name": s.name,
                        "nodes": [s.before.n_nodes, s.after.n_nodes],
                        "unique_thresholds": [s.before.n_unique_splits,
                                              s.after.n_unique_splits],
                        "n_leaves": [s.before.n_leaves, s.after.n_leaves],
                        "n_features": [s.before.n_features,
                                       s.after.n_features]}
                       for s in res.stats],
            "node_reduction": 1.0 - a.n_nodes / max(b.n_nodes, 1),
            "threshold_reduction":
                1.0 - a.n_unique_splits / max(b.n_unique_splits, 1),
            "feature_reduction":
                1.0 - a.n_features / max(b.n_features, 1),
            "padding_reduction": 1.0 - a.n_leaves / max(b.n_leaves, 1),
            "engines": eng_rec,
        })
    return t, records


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--json", action="store_true",
                    help="also write BENCH_optim.json at the repo root")
    ap.add_argument("--repeats", type=int, default=5)
    args = ap.parse_args(argv)

    tbl, records = run(repeats=args.repeats)
    tbl.print()
    tbl.save()
    best = max((r["engines"][e]["speedup"] for r in records
                for e in r["engines"]), default=None)
    if best is not None:
        print(f"\nbest -O2 vs -O0 wall-clock ratio: {best:.2f}x")
    if args.json:
        snapshot = {
            "scale": SCALE,
            "records": records,
            "best_speedup": best,
        }
        save_json(f"{tbl.name}_raw", snapshot)
        if SCALE != "default":      # same source of truth as run()'s suffix
            print(f"scale={SCALE}: {SNAPSHOT} left untouched")
        else:
            with open(SNAPSHOT, "w") as f:
                json.dump(snapshot, f, indent=1, default=float)
            print(f"snapshot written to {SNAPSHOT}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
