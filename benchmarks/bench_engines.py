"""Engine A/B harness: every XLA engine (and optionally the Pallas
variants) timed on the same forests, with the bit-matmul vs seed-QS
speedup called out — the acceptance gate for the MXU bit-matmul work.

    PYTHONPATH=src python -m benchmarks.bench_engines            # table
    PYTHONPATH=src python -m benchmarks.bench_engines --json     # + snapshot

``--json`` writes ``BENCH_engines.json`` at the repo root (a perf
trajectory for future PRs) in addition to the usual CSV under
``experiments/bench/``.  Shapes follow REPRO_BENCH_SCALE; every scale
includes at least one forest with >= 64 leaves/tree, where eliminating
``mask_reduce``'s (B, T, N, W) intermediate matters most.

The candidate set comes from ``core.registry`` (via
``engine_select.default_engines``) — engines registered once appear here
automatically; there is no engine list to keep in sync.

A second table times integer vs float accumulation on the same quantized
forests (``QuantSpec(bits=16)`` vs ``QuantSpec(bits=16,
int_accum=True)``, docs/QUANT.md §3): identical thresholds and leaves,
only the accumulator dtype differs, so the ratio isolates the
accumulation cost. Both variants are bit-exact vs the quantized oracle —
this is a pure wall-clock comparison.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

import numpy as np

from repro import core
from repro.core import engine_select

from .common import Table, save_json, scale_pick, time_predict, \
    us_per_instance

REPO_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
SNAPSHOT = os.path.join(REPO_ROOT, "BENCH_engines.json")


def shapes():
    # (n_trees, n_leaves, n_features, batch)
    return scale_pick(
        [(100, 32, 136, 256), (200, 64, 136, 512)],
        [(100, 32, 136, 256), (200, 64, 136, 512), (400, 64, 136, 512)],
        [(400, 32, 136, 1024), (1024, 64, 136, 1024),
         (1024, 128, 136, 1024)],
    )


def run(engines, repeats: int = 5):
    """Benchmark the given engine tuple (resolve defaults in the caller).

    A subset of the default matrix gets a ``bench_engines_subset`` table:
    its 'fastest' column only ranks the engines that ran, so its
    artifacts must never replace the canonical full-matrix ones — the
    rename protects every caller of ``run()``, not just ``main()``."""
    subset = set(engines) != set(engine_select.default_engines())
    cols = ["trees", "leaves", "batch"] + [f"{e}_us" for e in engines] + \
        ["fastest", "bitmm_vs_qs"]
    t = Table("bench_engines_subset" if subset else "bench_engines", cols)
    records = []
    for (T, L, d, B) in shapes():
        forest = core.random_forest_ir(T, L, d, seed=T + L)
        X = np.random.default_rng(0).normal(0, 1, size=(B, d))
        us = {}
        for e in engines:
            pred = engine_select.ENGINE_FACTORIES[e](forest)
            us[e] = us_per_instance(
                time_predict(lambda: pred.predict(X), repeats=repeats), B)
        fastest = min(us, key=us.get)
        # None (JSON null), not NaN: NaN is invalid strict JSON and would
        # make the --engines subset artifacts unparseable
        speedup = us["qs"] / us["qs-bitmm"] \
            if "qs" in us and "qs-bitmm" in us else None
        t.add(T, L, B, *(f"{us[e]:.1f}" for e in engines), fastest,
              f"{speedup:.2f}x" if speedup is not None else "n/a")
        records.append({"trees": T, "leaves": L, "features": d, "batch": B,
                        "us_per_instance": us, "fastest": fastest,
                        "speedup_bitmm_vs_qs": speedup})
    return t, records


def run_int(engines, repeats: int = 5):
    """Integer vs float accumulation on identical quantized forests.

    Same thresholds, same integer leaves — only the accumulator dtype
    (and the final descale) differs between the two timed predictors, so
    ``int_vs_f32`` isolates what integer accumulation costs (or saves)
    per engine on this backend."""
    cols = ["trees", "leaves", "batch", "engine", "f32_us", "int_us",
            "int_vs_f32"]
    t = Table("bench_engines_int", cols)
    records = []
    for (T, L, d, B) in shapes():
        forest = core.random_forest_ir(T, L, d, seed=T + L)
        rng = np.random.default_rng(0)
        X = rng.normal(0, 1, size=(B, d))
        X_cal = np.random.default_rng(1).normal(0, 1, size=(512, d))
        qf32 = core.quantize_forest(forest, X_cal,
                                    core.QuantSpec(bits=16))
        qint = core.quantize_forest(forest, X_cal,
                                    core.QuantSpec(bits=16,
                                                   int_accum=True))
        for e in engines:
            us = {}
            for tag, qf in (("f32", qf32), ("int", qint)):
                pred = engine_select.ENGINE_FACTORIES[e](qf)
                us[tag] = us_per_instance(
                    time_predict(lambda: pred.predict(X),
                                 repeats=repeats), B)
            ratio = us["f32"] / us["int"]
            t.add(T, L, B, e, f"{us['f32']:.1f}", f"{us['int']:.1f}",
                  f"{ratio:.2f}x")
            records.append({"trees": T, "leaves": L, "batch": B,
                            "engine": e, "f32_us": us["f32"],
                            "int_us": us["int"],
                            "speedup_int_vs_f32": ratio})
    return t, records


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--json", action="store_true",
                    help="also write BENCH_engines.json at the repo root")
    ap.add_argument("--engines", type=str, default=None,
                    help="comma-separated engine subset "
                         f"(default: {engine_select.default_engines()})")
    ap.add_argument("--repeats", type=int, default=5)
    args = ap.parse_args(argv)

    engines = list(dict.fromkeys(args.engines.split(","))) \
        if args.engines else None
    if engines:
        unknown = [e for e in engines
                   if e not in engine_select.ENGINE_FACTORIES]
        if unknown:
            ap.error(f"unknown engine(s) {unknown}; choose from "
                     f"{sorted(engine_select.ENGINE_FACTORIES)}")
    engines_run = tuple(engines) if engines \
        else engine_select.default_engines()
    tbl, records = run(engines_run, repeats=args.repeats)
    subset = tbl.name.endswith("_subset")
    tbl.print()
    tbl.save()
    best = max((r["speedup_bitmm_vs_qs"] for r in records
                if r["leaves"] >= 64
                and r["speedup_bitmm_vs_qs"] is not None), default=None)
    if best is not None:
        print(f"\nbitmm vs seed-QS speedup on L>=64 forests: "
              f"best {best:.2f}x")
    int_tbl, int_records = run_int(engines_run, repeats=args.repeats)
    print()
    int_tbl.print()
    int_tbl.save()
    if args.json:
        snapshot = {
            "scale": os.environ.get("REPRO_BENCH_SCALE", "default"),
            "engines": list(engines_run),
            "records": records,
            "best_bitmm_vs_qs_L64": best,
            "int_records": int_records,
        }
        save_json(f"{tbl.name}_raw", snapshot)
        if subset:
            print(f"--engines subset: {SNAPSHOT} left untouched")
        else:
            with open(SNAPSHOT, "w") as f:
                json.dump(snapshot, f, indent=1, default=float)
            print(f"snapshot written to {SNAPSHOT}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
