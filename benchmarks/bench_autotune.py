"""Zero-shot compilation (-Os) benchmark: what does the learned cost
model buy over measuring everything?

    PYTHONPATH=src python -m benchmarks.bench_autotune         # tables
    PYTHONPATH=src python -m benchmarks.bench_autotune --json  # + snapshot

Three experiments (docs/AUTOTUNE.md acceptance):

  * **fleet cold start** — N tenant shapes, time-to-first-served-
    prediction per tenant under ``mode="predict"`` (one compile + the
    feedback quick-bench) vs the full measured sweep over the same
    candidate axes (engines × ``opt_levels=(1, 2)``, every candidate
    compiled and benched, shared-IR on — the strongest baseline).  The
    claim: ≥5× faster in aggregate.
  * **prediction quality** — train on a shape grid, full-sweep held-out
    shapes the model never saw, and compare the *measured* us/instance
    of the model's pick against the measured winner's.  The claim: the
    pick is within 10% on ≥80% of shapes; every miss is listed with its
    actual ratio (honest-measurement rule: misses are data, not noise
    to hide).
  * **shared-IR sweeps** — a full sweep with optimizer variants
    (``opt_levels=(1, 2)``), ``share_ir`` off vs on.  Off re-runs the
    optimizer middle-end per candidate (engines × levels); on runs it
    once per (quant, opt) point and candidate pruning skips provably
    identical post-dedup pipelines.  The claim: ≥2× lower sweep
    wall-clock with the winner unchanged.

CPU-container caveat (PR-1 measurement discipline): all numbers are
relative comparisons of XLA programs on this host; the model itself is
device-fingerprinted, so a cache trained here predicts *for* here.
``--json`` writes ``BENCH_autotune.json`` at the repo root plus raw
records and CSVs under ``experiments/bench/`` — one run produces all
three artifacts (PR-1 artifact-consistency rule); non-default scales
suffix the CSV/raw names and leave the canonical snapshot untouched.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import tempfile
import time

import numpy as np

from repro import core, tune
from repro.core import engine_select

from .common import SCALE, Table, save_json, scale_pick

REPO_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
SNAPSHOT = os.path.join(REPO_ROOT, "BENCH_autotune.json")
BATCH = 256


def shapes():
    """(train, held_out, fleet) shape lists per scale: (T, L, d).  The
    train grid brackets the others — held-out and fleet shapes are
    interpolation targets the model never saw, not extrapolations."""
    train = scale_pick(
        [(16, 16, 16), (64, 16, 16), (16, 64, 16), (64, 64, 16),
         (32, 32, 16), (128, 32, 16)],
        [(16, 16, 32), (32, 16, 32), (64, 16, 32), (128, 16, 32),
         (256, 16, 32), (16, 64, 32), (32, 64, 32), (64, 64, 32),
         (128, 64, 32), (256, 64, 32), (16, 32, 32), (32, 32, 32),
         (64, 32, 32), (128, 32, 32), (256, 32, 32)],
        [(16, 16, 32), (32, 16, 32), (64, 16, 32), (128, 16, 32),
         (256, 16, 32), (512, 16, 32), (16, 64, 32), (32, 64, 32),
         (64, 64, 32), (128, 64, 32), (256, 64, 32), (512, 64, 32),
         (16, 32, 32), (64, 32, 32), (256, 32, 32), (512, 32, 32)],
    )
    held_out = scale_pick(
        [(24, 16, 16), (48, 32, 16), (96, 16, 16)],
        [(24, 16, 32), (48, 16, 32), (96, 16, 32), (192, 16, 32),
         (24, 32, 32), (96, 32, 32), (48, 64, 32), (96, 64, 32),
         (192, 64, 32), (192, 32, 32)],
        [(24, 16, 32), (48, 16, 32), (96, 16, 32), (192, 16, 32),
         (384, 16, 32), (24, 32, 32), (96, 32, 32), (384, 32, 32),
         (48, 64, 32), (96, 64, 32), (192, 64, 32), (384, 64, 32)],
    )
    fleet = scale_pick(
        [(20, 16, 16), (40, 32, 16), (80, 16, 16), (112, 32, 16)],
        [(20, 16, 32), (40, 16, 32), (56, 32, 32), (80, 32, 32),
         (112, 16, 32), (144, 64, 32), (176, 32, 32), (224, 64, 32)],
        [(20, 16, 32), (40, 16, 32), (56, 32, 32), (80, 32, 32),
         (112, 16, 32), (144, 64, 32), (176, 32, 32), (224, 64, 32),
         (288, 16, 32), (320, 64, 32), (416, 32, 32), (448, 64, 32)],
    )
    return train, held_out, fleet


def _forest(T, L, d, seed):
    return core.quantize_forest(core.random_forest_ir(T, L, d, seed=seed),
                                None)


OPT_LEVELS = (1, 2)      # the candidate axis -Os predicts over: every
#                          sweep here is engines × {plain, @O1, @O2}


def train_model(cache, train_shapes, engines, repeats):
    """Populate ``cache`` with measured sweeps over the train grid and
    fit the cost model from it.  Returns (model, model_path, seconds)."""
    engine_select.clear_cache()
    t0 = time.perf_counter()
    reps = max(repeats, 5)     # training labels are the model's ground
    #                            truth: worth steadier medians than the
    #                            per-tenant sweeps pay
    for i, (T, L, d) in enumerate(train_shapes):
        engine_select.choose(_forest(T, L, d, seed=i), BATCH,
                             engines=engines, opt_levels=OPT_LEVELS,
                             cache_path=cache, repeats=reps)
    sweep_s = time.perf_counter() - t0
    model_path = os.path.join(os.path.dirname(cache), "cost_model.json")
    model = tune.train_from_cache(cache, save_to=model_path)
    print(f"[train] {len(train_shapes)} sweeps in {sweep_s:.1f}s → "
          f"{model.n_rows} rows, resid_sigma={model.resid_sigma:.3f}")
    return model, model_path, sweep_s


def bench_fleet(tmp, model_path, fleet_shapes, engines, repeats):
    """Cold-start TTFP per tenant: full measured sweep vs -Os predict.
    Both paths start from an empty mem cache and an empty disk cache and
    are timed through the first served prediction."""
    suffix = "" if SCALE == "default" else f"_{SCALE}"
    t = Table(f"bench_autotune_fleet{suffix}",
              ["tenant", "shape", "full_sweep_s", "os_s", "speedup",
               "full_winner", "os_pick", "confidence"])
    rows, full_total, os_total = [], 0.0, 0.0
    for i, (T, L, d) in enumerate(fleet_shapes):
        f = _forest(T, L, d, seed=100 + i)
        X = np.random.default_rng(i).normal(size=(BATCH, f.n_features_in))

        engine_select.clear_cache()
        full_cache = os.path.join(tmp, f"fleet_full_{i}.json")
        t0 = time.perf_counter()
        cf = engine_select.choose(f, BATCH, engines=engines,
                                  opt_levels=OPT_LEVELS,
                                  cache_path=full_cache, repeats=repeats)
        cf.predictor.predict(X)
        full_s = time.perf_counter() - t0

        engine_select.clear_cache()
        os_cache = os.path.join(tmp, f"fleet_os_{i}.json")
        t0 = time.perf_counter()
        co = engine_select.choose(f, BATCH, engines=engines,
                                  opt_levels=OPT_LEVELS,
                                  cache_path=os_cache, mode="predict",
                                  cost_model=model_path,
                                  confidence_threshold=0.0,
                                  repeats=repeats)
        co.predictor.predict(X)
        os_s = time.perf_counter() - t0

        full_total += full_s
        os_total += os_s
        conf = f"{co.confidence:.3f}" if co.confidence is not None else "-"
        t.add(f"t{i}", f"T{T}/L{L}/d{d}", f"{full_s:.3f}", f"{os_s:.3f}",
              f"{full_s / os_s:.1f}x", cf.engine, co.engine, conf)
        rows.append({"tenant": f"t{i}", "shape": [T, L, d],
                     "full_sweep_s": full_s, "os_s": os_s,
                     "full_winner": cf.engine, "os_pick": co.engine,
                     "predicted": co.predicted,
                     "confidence": co.confidence})
    speedup = full_total / os_total
    rec = {"n_tenants": len(fleet_shapes), "engines": list(engines),
           "opt_levels": list(OPT_LEVELS),
           "n_candidates": len(engines) * (1 + len(OPT_LEVELS)),
           "full_total_s": full_total, "os_total_s": os_total,
           "speedup": speedup, "target": 5.0,
           "met": speedup >= 5.0, "tenants": rows}
    t.print()
    t.save()
    print(f"[fleet] time-to-first-prediction, {len(fleet_shapes)} cold "
          f"tenants: full={full_total:.1f}s -Os={os_total:.1f}s → "
          f"{speedup:.1f}x (target ≥5x: "
          f"{'MET' if rec['met'] else 'NOT MET'})")
    return rec


def bench_quality(tmp, model, held_out, engines, repeats):
    """Held-out prediction quality: the model's pick, measured, vs the
    measured winner.  within-10% fraction is the headline; every miss
    is listed with its measured ratio."""
    suffix = "" if SCALE == "default" else f"_{SCALE}"
    t = Table(f"bench_autotune_quality{suffix}",
              ["shape", "predicted", "winner", "pick_us", "winner_us",
               "excess", "within_10pct"])
    rows = []
    for i, (T, L, d) in enumerate(held_out):
        f = _forest(T, L, d, seed=500 + i)
        meta = engine_select.shape_meta(f, BATCH)
        assess = model.assess(meta, engines)
        pick = engines[int(assess["order"][0])]

        engine_select.clear_cache()
        cache = os.path.join(tmp, f"ho_{i}.json")
        c = engine_select.choose(f, BATCH, engines=engines,
                                 cache_path=cache,
                                 repeats=max(repeats, 5))
        with open(cache) as fh:
            bench_us = json.load(fh)[c.key]["bench_us"]
        pick_us, win_us = bench_us[pick], bench_us[c.engine]
        excess = pick_us / win_us - 1.0
        ok = excess <= 0.10
        t.add(f"T{T}/L{L}/d{d}", pick, c.engine, f"{pick_us:.1f}",
              f"{win_us:.1f}", f"{excess * 100:+.1f}%",
              "yes" if ok else "NO")
        rows.append({"shape": [T, L, d], "predicted": pick,
                     "winner": c.engine, "pick_us": pick_us,
                     "winner_us": win_us, "excess": excess,
                     "within_10pct": ok,
                     "confidence": assess["confidence"]})
    n_ok = sum(r["within_10pct"] for r in rows)
    frac = n_ok / len(rows)
    misses = [r for r in rows if not r["within_10pct"]]
    rec = {"n_held_out": len(rows), "n_within_10pct": n_ok,
           "fraction": frac, "target": 0.8, "met": frac >= 0.8,
           "misses": misses, "shapes": rows}
    t.print()
    t.save()
    print(f"[quality] {n_ok}/{len(rows)} held-out shapes within 10% of "
          f"the measured winner ({frac * 100:.0f}%, target ≥80%: "
          f"{'MET' if rec['met'] else 'NOT MET'})")
    for m in misses:
        T, L, d = m["shape"]
        print(f"[quality]   miss: T{T}/L{L}/d{d} picked "
              f"{m['predicted']} at {m['excess'] * 100:+.1f}% over "
              f"{m['winner']}")
    return rec


def bench_shared_ir(engines, repeats):
    """One optimizer-variant sweep (engines × ``opt_levels=(1, 2)``),
    ``share_ir`` off vs on, winners compared.  No disk cache — both runs
    measure every candidate from scratch."""
    T, L, d = scale_pick((128, 32, 32), (1024, 64, 64), (1536, 96, 64))
    reps = repeats
    f = _forest(T, L, d, seed=7)
    times, winners, timings, pruned = {}, {}, {}, {}
    for flag in (False, True):
        engine_select.clear_cache()
        t0 = time.perf_counter()
        c = engine_select.choose(f, BATCH, engines=engines,
                                 opt_levels=(1, 2), cache_path=None,
                                 repeats=reps, share_ir=flag)
        times[flag] = time.perf_counter() - t0
        winners[flag] = c.engine
        timings[flag] = dict(c.timings)
        pruned[flag] = list(c.pruned)
    speedup = times[False] / times[True]
    # two independent sweeps re-measure every candidate: a near-tie can
    # flip the argmin either way regardless of share_ir.  When the
    # names differ, re-bench the two picks head-to-head with far more
    # repeats and call the winner unchanged iff they are a statistical
    # tie (≤5% apart) — the gap is reported either way.
    gap = 0.0
    if winners[False] != winners[True]:
        facs = engine_select._candidate_factories(
            f, tuple(engines), None, None, 1, opt_levels=(1, 2),
            opt_cache={})
        X = engine_select._bench_rows(f, engine_select.bucket_batch(BATCH),
                                      0)
        head = {w: engine_select._bench_once(facs[w](), X, repeats=15)
                for w in {winners[False], winners[True]}}
        gap = (max(head.values()) - min(head.values())) \
            / min(head.values())
    unchanged = winners[False] == winners[True] or gap <= 0.05
    rec = {"shape": [T, L, d], "engines": list(engines),
           "opt_levels": [1, 2], "n_candidates": 3 * len(engines),
           "repeats": reps,
           "off_s": times[False], "on_s": times[True],
           "speedup": speedup, "target": 2.0,
           "winner_off": winners[False], "winner_on": winners[True],
           "winner_gap": gap, "winner_unchanged": unchanged,
           "pruned": pruned[True],
           "met": speedup >= 2.0 and unchanged}
    print(f"[shared-ir] T{T}/L{L}/d{d}, {3 * len(engines)} candidates: "
          f"off={times[False]:.1f}s on={times[True]:.1f}s → "
          f"{speedup:.1f}x, winner {winners[False]} → {winners[True]} "
          f"(gap {gap * 100:.1f}%, {len(pruned[True])} pruned; target "
          f"≥2x at unchanged winner: "
          f"{'MET' if rec['met'] else 'NOT MET'})")
    return rec


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--json", action="store_true",
                    help="also write BENCH_autotune.json at the repo "
                         "root (default scale only)")
    ap.add_argument("--repeats", type=int, default=3)
    args = ap.parse_args(argv)

    print(f"[bench_autotune] scale={SCALE} "
          f"fingerprint={engine_select.fingerprint_hash()}")
    engines = engine_select.default_engines(include_pallas=False)
    train, held_out, fleet = shapes()
    suffix = "" if SCALE == "default" else f"_{SCALE}"

    with tempfile.TemporaryDirectory() as tmp:
        cache = os.path.join(tmp, "train_cache.json")
        model, model_path, train_s = train_model(cache, train, engines,
                                                 args.repeats)
        fleet_rec = bench_fleet(tmp, model_path, fleet, engines,
                                args.repeats)
        qual_rec = bench_quality(tmp, model, held_out, engines,
                                 args.repeats)
    ir_rec = bench_shared_ir(engines, args.repeats)
    engine_select.clear_cache()

    snapshot = {
        "scale": SCALE,
        "batch": BATCH,
        "engines": list(engines),
        "train": {"n_shapes": len(train), "sweep_s": train_s,
                  "n_rows": model.n_rows,
                  "resid_sigma": model.resid_sigma},
        "fleet_cold_start": fleet_rec,
        "prediction_quality": qual_rec,
        "shared_ir_sweep": ir_rec,
        "all_targets_met": (fleet_rec["met"] and qual_rec["met"]
                            and ir_rec["met"]),
    }
    if args.json:
        save_json(f"bench_autotune{suffix}_raw", snapshot)
        if SCALE != "default":
            print(f"scale={SCALE}: {SNAPSHOT} left untouched")
        else:
            with open(SNAPSHOT, "w") as f:
                json.dump(snapshot, f, indent=1, default=float)
            print(f"snapshot written to {SNAPSHOT}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
