"""Paper Table 4: % unique nodes kept after RapidScorer equivalent-node
merging, float vs quantized, across tree counts.

Claim under test: quantization collapses unique thresholds only on
heavy-tailed features (EEG), elsewhere merging rates are unchanged;
merging rates fall with tree count (more trees → more shared thresholds).

Beyond the paper: the ``quant+dedup`` rows run the optimizer middle-end's
``dedup_thresholds`` pass (``repro.optim``, docs/OPTIM.md) on the
quantized forest first and report the unique-threshold count it leaves —
so the quantization-collapse claim is checked against the *compiler's*
canonicalization, not just RapidScorer's internal merge table.  Cells
read ``<kept %> (<unique count>)``.
"""
from __future__ import annotations

from repro import core, optim
from repro.data import datasets
from repro.trees.random_forest import RandomForest, RandomForestConfig

from .common import Table, scale_pick

DATASETS = ["adult", "eeg", "fashion", "magic", "mnist"]


def run() -> Table:
    tree_counts = scale_pick([32, 64], [128, 256], [128, 256, 512, 1024])
    n_leaves = scale_pick(32, 64, 64)
    n_samples = scale_pick(1500, 3000, 8000)

    t = Table("table4_merging",
              ["dataset", "type"] + [f"T={T}" for T in tree_counts])
    for name in DATASETS:
        ds = datasets.load(name, n=n_samples)
        row_f, row_q, row_d = [], [], []
        for T in tree_counts:
            rf = RandomForest(RandomForestConfig(
                n_trees=T, max_leaves=n_leaves, seed=0)).fit(
                ds.X_train, ds.y_train)
            forest = core.from_random_forest(rf)
            row_f.append(f"{core.merge_stats(forest)*100:.1f}%")
            qf = core.quantize_forest(forest, ds.X_train)
            row_q.append(f"{core.merge_stats(qf)*100:.1f}%")
            # optimizer cross-check: dedup_thresholds canonicalizes and
            # drops dominated splits; the unique count it leaves is the
            # collapse the compiler actually exploits
            dq = optim.optimize(qf, ("dedup_thresholds",)).forest
            row_d.append(f"{core.merge_stats(dq)*100:.1f}% "
                         f"({optim.n_unique_splits(dq)})")
        t.add(name, "float", *row_f)
        t.add(name, "quant", *row_q)
        t.add(name, "quant+dedup", *row_d)
    return t


def main():
    tbl = run()
    tbl.print()
    tbl.save()


if __name__ == "__main__":
    main()
