"""Paper Table 4: % unique nodes kept after RapidScorer equivalent-node
merging, float vs quantized, across tree counts.

Claim under test: quantization collapses unique thresholds only on
heavy-tailed features (EEG), elsewhere merging rates are unchanged;
merging rates fall with tree count (more trees → more shared thresholds).
"""
from __future__ import annotations

from repro import core
from repro.data import datasets
from repro.trees.random_forest import RandomForest, RandomForestConfig

from .common import Table, scale_pick

DATASETS = ["adult", "eeg", "fashion", "magic", "mnist"]


def run() -> Table:
    tree_counts = scale_pick([32, 64], [128, 256], [128, 256, 512, 1024])
    n_leaves = scale_pick(32, 64, 64)
    n_samples = scale_pick(1500, 3000, 8000)

    t = Table("table4_merging",
              ["dataset", "type"] + [f"T={T}" for T in tree_counts])
    for name in DATASETS:
        ds = datasets.load(name, n=n_samples)
        row_f, row_q = [], []
        for T in tree_counts:
            rf = RandomForest(RandomForestConfig(
                n_trees=T, max_leaves=n_leaves, seed=0)).fit(
                ds.X_train, ds.y_train)
            forest = core.from_random_forest(rf)
            row_f.append(f"{core.merge_stats(forest)*100:.1f}%")
            qf = core.quantize_forest(forest, ds.X_train)
            row_q.append(f"{core.merge_stats(qf)*100:.1f}%")
        t.add(name, "float", *row_f)
        t.add(name, "quant", *row_q)
    return t


def main():
    tbl = run()
    tbl.print()
    tbl.save()


if __name__ == "__main__":
    main()
