"""Paper Table 5: classification traversal runtime (µs/instance) for
(quantized) QS/VQS/RS/IE/NA across the 5 classification datasets.

Forests are trained (accuracy shown alongside runtime so correctness is
auditable); engine mapping per DESIGN.md §2.
"""
from __future__ import annotations

import numpy as np

from repro import core
from repro.data import datasets
from repro.trees.random_forest import RandomForest, RandomForestConfig

from .common import Table, scale_pick, time_predict, us_per_instance

DATASETS = ["magic", "mnist", "adult", "eeg", "fashion"]
ENGINES = ["rapidscorer", "bitvector", "native", "unrolled", "gemm"]
PAPER_NAME = {"rapidscorer": "RS", "bitvector": "QS/VQS", "native": "NA",
              "unrolled": "IE", "gemm": "GEMM(new)"}


def run() -> tuple[Table, Table]:
    n_trees = scale_pick(64, 128, 1024)
    n_leaves = scale_pick(32, 64, 64)
    n_samples = scale_pick(1500, 3000, 8000)
    batch = scale_pick(256, 512, 2048)

    t_us = Table("table5_classification_us",
                 ["dataset", "quant"] +
                 [PAPER_NAME[e] for e in ENGINES] + ["best"])
    t_sp = Table("table5_classification_speedup",
                 ["dataset", "quant"] +
                 [PAPER_NAME[e] for e in ENGINES] + ["accuracy"])
    for name in DATASETS:
        ds = datasets.load(name, n=n_samples)
        rf = RandomForest(RandomForestConfig(
            n_trees=n_trees, max_leaves=n_leaves, seed=0)).fit(
            ds.X_train, ds.y_train)
        base_forest = core.from_random_forest(rf)
        rng = np.random.default_rng(1)
        X = ds.X_test[rng.integers(0, ds.X_test.shape[0], size=batch)]

        na_float = None
        for quant in (False, True):
            forest = core.quantize_forest(base_forest, ds.X_train) \
                if quant else base_forest
            res, acc = {}, None
            for e in ENGINES:
                pred = core.compile_forest(forest, engine=e)
                sec = time_predict(lambda: pred.predict(X))
                res[e] = us_per_instance(sec, batch)
                if acc is None:
                    acc = (pred.predict_class(ds.X_test) ==
                           ds.y_test).mean()
            if not quant:
                na_float = res["native"]
            best = min(res, key=res.get)
            t_us.add(name, "q" if quant else "-",
                     *[f"{res[e]:.2f}" for e in ENGINES], PAPER_NAME[best])
            t_sp.add(name, "q" if quant else "-",
                     *[f"{na_float / res[e]:.2f}x" for e in ENGINES],
                     f"{acc*100:.2f}%")
    return t_us, t_sp


def main():
    for tbl in run():
        tbl.print()
        tbl.save()


if __name__ == "__main__":
    main()
