"""Paper Table 2: ranking-model traversal runtime (µs/instance) on the MSN
dataset, GBTs with {1k,5k,10k,20k} trees × {32,64} leaves.

Reproduction notes:
  * engine mapping (DESIGN.md §2): QS/VQS → bitvector, RS → rapidscorer,
    NA → native, IE → unrolled, + the beyond-paper gemm engine;
  * runtime is independent of learned leaf values, so the sweep uses
    `random_forest_ir` ensembles with MSN's feature count (the paper's
    observation — runtime depends on forest shape only — is what licenses
    this; training 20k trees in CI would add hours for identical timings);
  * one *trained* GBT row (scaled tree count) anchors the synthetic rows.
"""
from __future__ import annotations

import numpy as np

from repro import core
from repro.data import datasets

from .common import Table, scale_pick, time_predict, us_per_instance

ENGINES = ["rapidscorer", "bitvector", "native", "unrolled", "gemm"]
PAPER_NAME = {"rapidscorer": "RS", "bitvector": "QS/VQS", "native": "NA",
              "unrolled": "IE", "gemm": "GEMM(new)"}


UNROLL_CAP = 1000    # the IF-ELSE analogue is compile-bound beyond this —
                     # the paper's own IF-ELSE codegen-scaling problem,
                     # reproduced as a compile-time wall (noted in
                     # EXPERIMENTS.md §Table2)


def run(quantized: bool = False) -> Table:
    tree_counts = scale_pick([200, 1000], [1000, 2000], [1000, 5000, 10000,
                                                         20000])
    leaf_counts = scale_pick([32], [32, 64], [32, 64])
    batch = scale_pick(256, 512, 4096)
    d = 136                                        # MSN feature count

    tag = "q" if quantized else ""
    t = Table(f"table2_ranking{'_quant' if quantized else ''}",
              ["trees", "leaves"] +
              [f"{tag}{PAPER_NAME[e]}_us" for e in ENGINES] +
              [f"{tag}{PAPER_NAME[e]}_speedup" for e in ENGINES])
    rng = np.random.default_rng(0)
    X = rng.normal(0, 1, size=(batch, d))
    for L in leaf_counts:
        for T in tree_counts:
            forest = core.random_forest_ir(T, L, d, n_classes=1, seed=T + L)
            if quantized:
                forest = core.quantize_forest(forest)
            res = {}
            for e in ENGINES:
                if e == "unrolled" and T > UNROLL_CAP:
                    res[e] = float("nan")
                    continue
                pred = core.compile_forest(forest, engine=e)
                sec = time_predict(lambda: pred.predict(X))
                res[e] = us_per_instance(sec, batch)
            na = res["native"]

            def fmt(x, suffix=""):
                import math
                return "-" if math.isnan(x) else f"{x:.2f}{suffix}"

            t.add(T, L, *[fmt(res[e]) for e in ENGINES],
                  *[fmt(na / res[e], "x") for e in ENGINES])
    return t


def run_trained_anchor() -> Table:
    """One trained-GBT row: confirms synthetic-forest timings match
    trained-forest timings for identical (T, L, d)."""
    T, L = scale_pick((100, 16), (400, 32), (1000, 32))
    ds = datasets.load("msn", n=scale_pick(1500, 4000, 8000))
    from repro.trees.gradient_boosting import (GradientBoosting,
                                               GradientBoostingConfig)
    gb = GradientBoosting(GradientBoostingConfig(
        n_trees=T, max_leaves=L, objective="l2", seed=0)).fit(
        ds.X_train, ds.y_train)
    trained = core.from_gradient_boosting(gb)
    synth = core.random_forest_ir(len(gb.trees), trained.n_leaves,
                                  ds.n_features, seed=1)
    batch = scale_pick(256, 1024, 4096)
    X = ds.X_test[np.random.default_rng(0).integers(
        0, ds.X_test.shape[0], size=batch)]
    t = Table("table2_trained_anchor",
              ["forest", "trees", "leaves", "depth", "RS_us", "QS_us",
               "NA_us"])
    for name, f in (("trained_gbt", trained), ("synthetic", synth)):
        row = []
        for e in ("rapidscorer", "bitvector", "native"):
            pred = core.compile_forest(f, engine=e)
            row.append(f"{us_per_instance(time_predict(lambda: pred.predict(X)), batch):.2f}")
        # NATIVE cost ∝ max depth (fori_loop trip count): trained leaf-wise
        # trees are deeper than balanced synthetic ones at equal leaf count
        t.add(name, f.n_trees, f.n_leaves, f.max_depth, *row)
    return t


def main():
    for tbl in (run(False), run(True), run_trained_anchor()):
        tbl.print()
        tbl.save()


if __name__ == "__main__":
    main()
